"""Failure detection + local/parallel recovery (paper §5.5, Fig. 19-21).

Detection: on invocation, the instance compares its local (term, hash)
with the daemon's piggybacked view — mismatch means the instance was
reclaimed and restarted cold (§5.5.1). The diff_rank delta decides local
vs parallel recovery: if many chunks are missing, a pre-selected group of
R recovery functions each restores `hash(key) % R == i`'s portion from
COS in parallel and serves GETs for that portion until the storage
function resumes (§5.5.2, RAMCloud-style but with *temporary* recovery
placement to survive cascading reclamations).
"""
from __future__ import annotations

import hashlib
import threading
import time
from concurrent.futures import ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set

from repro.core.cos import COS
from repro.core.faults import RetryPolicy
from repro.core.insertion_log import InsertionLog, Piggyback
from repro.core.sms import SMS, Slab


def _chunk_shard(key: str, groups: int) -> int:
    return int.from_bytes(hashlib.md5(key.encode()).digest()[:4],
                          "little") % groups


@dataclass
class RecoveryStats:
    detections: int = 0
    local_recoveries: int = 0
    parallel_recoveries: int = 0
    chunks_recovered: int = 0
    bytes_recovered: int = 0
    recovery_seconds: float = 0.0


@dataclass
class RecoverySession:
    fid: int
    group: List[int]
    pending: Set[str]
    recovered: Dict[str, bytes] = field(default_factory=dict)
    done: bool = False
    completed_at: Optional[float] = None      # clock time of phase 3
    # temporary cache placements in the recovery group: (rfid, chunk key)
    placements: List[tuple] = field(default_factory=list)


class RecoveryManager:
    def __init__(self, sms: SMS, cos: COS, logs: Dict[int, InsertionLog], *,
                 num_recovery_functions: int = 20, workers: int = 8,
                 retain_seconds: float = 60.0, writeback=None, clock=None,
                 thread_prefix: str = "recovery",
                 retry: Optional[RetryPolicy] = None):
        self.sms = sms
        self.cos = cos
        # unified retry policy (repro.core.faults) for recovery-time COS
        # downloads: a recovery session racing a transient COS blip must
        # retry rather than silently dropping chunks from the restore
        self.retry = retry or RetryPolicy(max_attempts=6,
                                          backoff_base_s=0.005,
                                          backoff_cap_s=0.25)
        # WritebackQueue (or None): chunks acked but not yet persisted to
        # COS are restored from its pending map — the async-writeback
        # durability contract (§5.3.2)
        self.writeback = writeback
        self.logs = logs
        self.R = num_recovery_functions
        # §5.5.2: recovery-group placements are TEMPORARY — they expire
        # this long after the session completes (swept by sweep_expired)
        self.retain_seconds = retain_seconds
        self.clock = clock                    # store Clock, or wall time
        self.stats = RecoveryStats()
        # per-shard prefix so a multi-daemon deployment's recovery pools
        # are tell-apart-able in thread dumps
        self._pool = ThreadPoolExecutor(max_workers=workers,
                                        thread_name_prefix=thread_prefix)
        self._lock = threading.RLock()
        # fid -> pre-selected recovery group (function ids)
        self.recovery_groups: Dict[int, List[int]] = {}
        # functions currently acting as a recovery function (one storage
        # function each, §5.5.2 phase 1)
        self._busy_recovery: Set[int] = set()
        self.sessions: Dict[int, RecoverySession] = {}
        # sessions displaced from `sessions` by a same-fid re-failure
        # while still running: their placements are still being
        # appended, so they are parked here and swept once done
        self._orphans: List[RecoverySession] = []

    def _now(self) -> float:
        return self.clock.now() if self.clock is not None \
            else time.monotonic()

    def shutdown(self) -> None:
        """Release the recovery worker pool. Without this every store
        leaks up to `workers` live recovery-* threads on close."""
        self._pool.shutdown(wait=True)

    # ---- group management (phase 1) -------------------------------------

    def assign_group(self, fid: int, candidates: List[int]) -> List[int]:
        """Pre-select (or refresh) the recovery group for a storage
        function from the non-recovering pool."""
        with self._lock:
            group = [c for c in candidates
                     if c != fid and c not in self._busy_recovery][:self.R]
            self.recovery_groups[fid] = group
            return group

    def _claim_group(self, fid: int, candidates: List[int]) -> List[int]:
        with self._lock:
            group = self.recovery_groups.get(fid, [])
            group = [g for g in group if g not in self._busy_recovery]
            for c in candidates:
                if len(group) >= self.R:
                    break
                if c != fid and c not in self._busy_recovery \
                        and c not in group:
                    group.append(c)
            for g in group:
                self._busy_recovery.add(g)
            return group

    def _release_group(self, group: List[int]) -> None:
        with self._lock:
            for g in group:
                self._busy_recovery.discard(g)

    # ---- detection (§5.5.1) ----------------------------------------------

    def check_failed(self, slab: Slab, daemon_view: Piggyback) -> bool:
        """Consistency check an invoked instance performs against the
        piggybacked insertion info."""
        failed = (slab.term != daemon_view.term
                  or slab.log_hash != daemon_view.hash)
        if failed and daemon_view.term > 0:
            self.note_detection()
            return True
        return False

    def note_detection(self) -> None:
        """Count one failure detection. The store calls this for the
        invoke-path `was_dead` case (an instance observed reclaimed at
        invocation) that a matching term/hash would otherwise hide from
        `check_failed` — both paths are real detections."""
        with self._lock:
            self.stats.detections += 1

    def needs_parallel(self, slab: Slab, daemon_view: Piggyback) -> bool:
        """diff_rank difference significantly larger than the recovery
        group size => parallel recovery (§5.5.1)."""
        diff = daemon_view.diff_rank - slab.diff_rank
        return diff > self.R

    # ---- recovery (§5.5.2) -------------------------------------------------

    def _download(self, keys: List[str]) -> Dict[str, bytes]:
        out: Dict[str, bytes] = {}
        for key in keys:
            try:
                if self.writeback is not None:   # pending map, then COS
                    data = self.retry.run(
                        lambda k=key:
                        self.writeback.read_through(f"chunk/{k}"))
                else:
                    data = self.retry.run(
                        lambda k=key: self.cos.get(f"chunk/{k}"))
            except Exception as e:                # noqa: BLE001
                if self.retry.classify(e) == RetryPolicy.PERMANENT:
                    raise
                # transient budget exhausted (COS outage): skip — the
                # chunk stays recoverable from COS once it heals, and
                # readers fall back to EC reconstruction meanwhile
                continue
            if data is not None:
                out[key] = data
        return out

    def recover_local(self, slab: Slab) -> int:
        """The failed instance replays its manifest and restores every
        missing chunk from COS by itself."""
        t0 = time.monotonic()
        log = self.logs.get(slab.fid)
        if log is None:                       # no durable history: no-op
            return 0
        manifest = log.manifest()
        missing = [k for k in manifest if slab.load(k) is None]
        got = self._download(missing)
        for key, data in got.items():
            slab.store(key, data)
        slab.term = log.term
        slab.log_hash = log.last_hash
        slab.diff_rank = log.diff_rank
        with self._lock:                  # pool workers may be running
            self.stats.local_recoveries += 1
            self.stats.chunks_recovered += len(got)
            self.stats.bytes_recovered += sum(len(v) for v in got.values())
            self.stats.recovery_seconds += time.monotonic() - t0
        return len(got)

    def recover_parallel(self, slab: Slab, candidates: List[int],
                         *, on_ready: Optional[Callable] = None
                         ) -> RecoverySession:
        """Phase 2: fan the missing chunk set out over the recovery group;
        each worker i downloads keys with hash(key) % R == i. Phase 3:
        the storage instance reabsorbs the chunks and resumes service."""
        t0 = time.monotonic()
        log = self.logs.get(slab.fid)
        if log is None:
            return RecoverySession(fid=slab.fid, group=[], pending=set(),
                                   done=True)
        manifest = log.manifest()
        missing = [k for k in manifest if slab.load(k) is None]
        group = self._claim_group(slab.fid, candidates)
        R = max(len(group), 1)
        session = RecoverySession(fid=slab.fid, group=group,
                                  pending=set(missing))
        with self._lock:
            # a prior session for this fid (re-failure inside
            # retain_seconds) leaves the dict here and would never be
            # swept — evict its temporary placements now. If it is
            # still RUNNING its workers are still appending placements
            # (an eviction now would miss the later ones): park it on
            # the orphan list for sweep_expired instead.
            prior = self.sessions.get(slab.fid)
            prior_placements: List[tuple] = []
            if prior is not None:
                if prior.done:
                    prior_placements = list(prior.placements)
                else:
                    self._orphans.append(prior)
            self.sessions[slab.fid] = session
        for rfid, key in prior_placements:
            rslab = self.sms.slabs.get(rfid)
            if rslab is not None:
                rslab.cache_delete(key)

        def worker(i: int) -> Dict[str, bytes]:
            mine = [k for k in missing if _chunk_shard(k, R) == i]
            got = self._download(mine)
            with self._lock:
                session.recovered.update(got)
                session.pending -= set(got.keys())
                # recovery functions hold the data TEMPORARILY in their
                # cache space and serve GETs for their portion
                if i < len(group) and group[i] in self.sms.slabs:
                    rslab = self.sms.slabs[group[i]]
                    for k2, v in got.items():
                        rslab.cache_put(k2, v)
                        session.placements.append((group[i], k2))
            return got

        futures = [self._pool.submit(worker, i) for i in range(R)]
        wait(futures)
        # phase 3: service resumption — the storage instance restores all
        for key, data in session.recovered.items():
            slab.store(key, data)
        slab.term = log.term
        slab.log_hash = log.last_hash
        slab.diff_rank = log.diff_rank
        session.done = True
        session.completed_at = self._now()
        self._release_group(group)
        with self._lock:                  # other sessions may be running
            self.stats.parallel_recoveries += 1
            self.stats.chunks_recovered += len(session.recovered)
            self.stats.bytes_recovered += sum(
                len(v) for v in session.recovered.values())
            self.stats.recovery_seconds += time.monotonic() - t0
        if on_ready:
            on_ready(session)
        return session

    def serve_during_recovery(self, fid: int, key: str) -> Optional[bytes]:
        """GETs rerouted to the recovery group while a storage function
        recovers (§5.5.2 phase 2)."""
        with self._lock:
            session = self.sessions.get(fid)
            if session is None:
                return None
            return session.recovered.get(key)

    def sweep_expired(self, now: Optional[float] = None) -> int:
        """Expire completed sessions past `retain_seconds` (the gc_tick
        hook): the recovery group's cache placements are TEMPORARY per
        §5.5.2 — evict them and drop the finished session. Returns the
        number of sessions expired."""
        if now is None:
            now = self._now()
        with self._lock:
            expired = [fid for fid, s in self.sessions.items()
                       if s.done and s.completed_at is not None
                       and now - s.completed_at >= self.retain_seconds]
            swept = [self.sessions.pop(fid) for fid in expired]
            keep: List[RecoverySession] = []
            for s in self._orphans:           # displaced sessions expire
                if s.done and s.completed_at is not None \
                        and now - s.completed_at >= self.retain_seconds:
                    swept.append(s)
                else:
                    keep.append(s)
            self._orphans = keep
        for session in swept:
            for rfid, key in session.placements:
                rslab = self.sms.slabs.get(rfid)
                if rslab is not None:
                    rslab.cache_delete(key)
        return len(swept)
