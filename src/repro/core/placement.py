"""Function groups + the PlaceChunk algorithm (paper §5.3.1, Fig. 5).

An FG is the logical scaling unit: `fg_size = k + p` functions, one per
EC chunk slot. PlaceChunk starts at function `chunk_id` and probes in
strides of `fg_size`, so two chunks of one object can never land on the
same function; the greedy oldest-open-FG-first policy fills (and seals)
old FGs before new ones.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

AUTOSCALE_LINEAR = "linear"
AUTOSCALE_DOUBLE = "double"


@dataclass
class FunctionMeta:
    fid: int
    fg_id: int
    slot: int                      # chunk-slot index within the FG
    capacity: int                  # HARDCAP bytes (storage partition)
    used: int = 0
    sealed: bool = False
    queue_depth: int = 0           # outstanding requests (two-queue combined)
    max_queue: int = 64

    @property
    def open(self) -> bool:
        return not self.sealed

    def has_room(self, nbytes: int) -> bool:
        return self.used + nbytes <= self.capacity

    def queue_ok(self) -> bool:
        return self.queue_depth < self.max_queue


@dataclass
class FunctionGroup:
    fg_id: int
    fids: List[int]
    sealed: bool = False


@dataclass
class PlacementStats:
    scale_outs: int = 0
    placements: int = 0
    probes: int = 0
    seals: int = 0


class PlacementManager:
    """Tracks open FGs of the LATEST GC-bucket and places chunks."""

    def __init__(self, fg_size: int, function_capacity: int, *,
                 autoscale: str = AUTOSCALE_LINEAR,
                 new_function_cb: Optional[Callable[[int, int, int], None]] = None):
        self.fg_size = fg_size
        self.function_capacity = function_capacity
        self.autoscale = autoscale
        self.functions: Dict[int, FunctionMeta] = {}
        self.fgs: Dict[int, FunctionGroup] = {}
        self.open_fg_ids: List[int] = []     # oldest first
        self._next_fid = 0
        self._next_fg = 0
        self.stats = PlacementStats()
        # callback(fid, fg_id, capacity): lets SMS allocate the slab and the
        # window register the function in the latest bucket
        self._new_function_cb = new_function_cb or (lambda *a: None)

    # ---- scaling ----------------------------------------------------------

    def _add_fg(self) -> FunctionGroup:
        fg = FunctionGroup(self._next_fg, [])
        self._next_fg += 1
        for slot in range(self.fg_size):
            fid = self._next_fid
            self._next_fid += 1
            self.functions[fid] = FunctionMeta(
                fid=fid, fg_id=fg.fg_id, slot=slot,
                capacity=self.function_capacity)
            fg.fids.append(fid)
            self._new_function_cb(fid, fg.fg_id, self.function_capacity)
        self.fgs[fg.fg_id] = fg
        self.open_fg_ids.append(fg.fg_id)
        self.stats.scale_outs += 1
        return fg

    def scale_out(self) -> None:
        if self.autoscale == AUTOSCALE_DOUBLE and self.open_fg_ids:
            for _ in range(max(1, len(self.open_fg_ids))):
                self._add_fg()
        else:
            self._add_fg()

    def _open_functions(self) -> List[int]:
        """Flat probe order: slot-major across open FGs, oldest FG first.
        Index i maps to (fg = i // fg_size by age, slot = i % fg_size)."""
        out: List[int] = []
        for fg_id in self.open_fg_ids:
            out.extend(self.fgs[fg_id].fids)
        return out

    def get_open_funcs(self, min_index: int) -> List[int]:
        """Paper's GetOpenFuncs: ensure at least min_index+1 open function
        slots exist, scaling out FG-at-a-time if needed."""
        funcs = self._open_functions()
        while len(funcs) <= min_index:
            self.scale_out()
            funcs = self._open_functions()
        return funcs

    # ---- sealing -----------------------------------------------------------

    def seal_fg(self, fg_id: int) -> None:
        fg = self.fgs[fg_id]
        if fg.sealed:
            return
        fg.sealed = True
        for fid in fg.fids:
            self.functions[fid].sealed = True
        if fg_id in self.open_fg_ids:
            self.open_fg_ids.remove(fg_id)
        self.stats.seals += 1

    def maybe_seal(self, fid: int) -> None:
        """Seal the whole FG once any member reaches HARDCAP (paper
        §5.3.1: 'all functions in that FG are sealed')."""
        f = self.functions[fid]
        if f.used >= f.capacity:
            self.seal_fg(f.fg_id)

    def carry_over_open_fgs(self) -> List[int]:
        """Open FGs survive GC into the new latest bucket (Fig. 4c)."""
        return list(self.open_fg_ids)

    # ---- PlaceChunk (Fig. 5) ----------------------------------------------

    def test_and_place(self, fid: int, nbytes: int) -> bool:
        """Paper semantics: a function accepts writes while UNDER HARDCAP;
        the write that crosses HARDCAP is accepted and then the whole FG
        seals (§5.3.1)."""
        f = self.functions[fid]
        if f.sealed or f.used >= f.capacity or not f.queue_ok():
            return False
        f.used += nbytes
        self.stats.placements += 1
        self.maybe_seal(fid)
        return True

    def place_chunk(self, chunk_id: int, nbytes: int) -> int:
        """Returns the function id that stores this chunk. chunk_id is the
        chunk's slot index within its object (0..fg_size-1)."""
        if not 0 <= chunk_id < self.fg_size:
            raise ValueError(f"chunk_id {chunk_id} not in [0,{self.fg_size})")
        func_ptr = chunk_id
        functions = self.get_open_funcs(func_ptr)
        while True:
            self.stats.probes += 1
            if func_ptr >= len(functions):
                functions = self.get_open_funcs(func_ptr)  # scale out
            elif not self.test_and_place(functions[func_ptr], nbytes):
                func_ptr += self.fg_size        # next FG, same slot
            else:
                return functions[func_ptr]

    def try_place_chunk(self, chunk_id: int, nbytes: int) -> Optional[int]:
        """`place_chunk` without the auto-scale: probes only EXISTING
        open functions and returns None when none accepts. Compaction
        and cache-space callers use this — re-placed read-path bytes
        must never spin up a new function group."""
        if not 0 <= chunk_id < self.fg_size:
            raise ValueError(f"chunk_id {chunk_id} not in [0,{self.fg_size})")
        functions = self._open_functions()
        func_ptr = chunk_id
        while func_ptr < len(functions):
            self.stats.probes += 1
            if self.test_and_place(functions[func_ptr], nbytes):
                return functions[func_ptr]
            func_ptr += self.fg_size            # next FG, same slot
        return None

    def release(self, fid: int, nbytes: int) -> None:
        f = self.functions.get(fid)
        if f is not None:
            f.used = max(0, f.used - nbytes)
