"""InfiniStore facade: an async, futures-based GET/PUT client API over
the SMS + COS layers (paper §5).

The client surface is non-blocking: `put_async` / `get_async` (and the
batched `put_many_async` / `get_many_async`) return a `StoreFuture`
(result / exception / done-callback; PUT futures carry the committed
version). The classic `put` / `get` / `put_many` / `get_many` are thin
blocking wrappers over the same path. All store mutation runs on one
internal client-daemon thread, so queued requests pipeline in submission
order and the data structures never see concurrent writers.

Ack point + durability contract (§5.3.2): a PUT acknowledges once every
fragment's chunks sit in SMS slabs AND the fragment sits in the
persistent buffer with its insertion-log node persisted — COS chunk
persistence is OFF the critical path, drained in the background by the
`WritebackQueue` (writer thread + `gc_tick`, bounded depth, retry with
backoff, `flush()` barrier). Until a chunk lands in COS, reads and
recovery are served from the persistent buffer / pending-writeback map,
so an instance failure between ack and persistence loses nothing.
`StoreConfig(async_writeback=False)` restores the legacy inline-COS ack
path (the benchmark baseline).

Payloads follow the `Payload` protocol: `bytes`, numpy arrays, or
device-backed `jax.Array`s are fragmented as flat uint8 views and reach
the bit-sliced GF(256) kernel without an intermediate `bytes` copy;
`get_array` / `get_many_arrays` return uint8 arrays the same way.

Also wired through: CAS versioning with multi-key batch commit (one
leader-sequenced metadata round per `put_many`), RS erasure coding,
PlaceChunk over the sliding-window GC-buckets, insertion logs, failure
detection + local/parallel recovery, demand caching, compaction,
large-object fragmentation, grouped per-function invokes on BOTH the
PUT and GET data paths, the two-queue scheme, and pay-per-access cost
accounting.
"""
from __future__ import annotations

import threading
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.clock import Clock
from repro.core.cos import COS
from repro.core.costmodel import CostLedger
from repro.core.ec import ECConfig, RSCodec
from repro.core.gc_window import BucketState, GCConfig, SlidingWindow
from repro.core.insertion_log import InsertionLog, Piggyback, PutRecord
from repro.core.payload import (as_u8, is_array_payload, needs_snapshot,
                                payload_nbytes, to_bytes)
from repro.core.placement import PlacementManager
from repro.core.recovery import RecoveryManager
from repro.core.sms import SMS
from repro.core.versioning import MetadataTable, PersistentBuffer
from repro.core.writeback import StoreFuture, WritebackQueue

MB = 1024 * 1024


@dataclass
class StoreConfig:
    ec: ECConfig = field(default_factory=ECConfig)       # RS(10+2)
    function_capacity: int = 1536 * MB                   # Lambda memory
    fragment_bytes: int = 200 * MB                       # §5.3.4
    small_request_bytes: int = 1 * MB                    # two-queue split
    gc: GCConfig = field(default_factory=GCConfig)
    num_recovery_functions: int = 20
    enable_recovery: bool = True       # False = SNR ablation (Fig. 22/23)
    provider_idle_reclaim: float = 3600.0                # FaaS reclamation
    cos_visibility_lag: float = 0.0
    autoscale: str = "linear"
    # estimated per-request function busy time model (seconds/byte + base),
    # calibrated to the paper's ~75 MB/s per-instance bandwidth
    busy_base_s: float = 0.001
    busy_per_byte_s: float = 1.0 / (75 * MB)
    # ---- async writeback (§5.3.2) --------------------------------------
    # True: PUT acks after SMS slabs + persistent buffer + insertion log;
    # COS chunk writes drain in the background. False: legacy inline COS
    # writes on the ack path (benchmark baseline / strict-persist mode).
    async_writeback: bool = True
    writeback_depth: int = 512         # queue bound (backpressure)
    writeback_retries: int = 8
    writeback_backoff_s: float = 0.005


@dataclass
class StoreStats:
    puts: int = 0
    gets: int = 0
    sms_chunk_hits: int = 0
    sms_chunk_misses: int = 0
    buffer_hits: int = 0
    migrations: int = 0
    compactions: int = 0
    degraded_hits: int = 0
    small_requests: int = 0
    large_requests: int = 0
    cas_rounds: int = 0            # multi-key CAS: metadata rounds issued
    gather_invokes: int = 0        # GET-side grouped per-function invokes
    array_payload_puts: int = 0    # PUTs that arrived as array payloads

    @property
    def hit_ratio(self) -> float:
        tot = self.sms_chunk_hits + self.sms_chunk_misses
        return self.sms_chunk_hits / tot if tot else 0.0


class InfiniStore:
    def __init__(self, cfg: Optional[StoreConfig] = None, *,
                 clock: Optional[Clock] = None,
                 cos_root: Optional[str] = None, seed: int = 0):
        # NOTE: cfg default must be constructed per-instance — a dataclass
        # default in the signature would be shared (and cross-mutated)
        # between every default-constructed store.
        self.cfg = cfg = cfg if cfg is not None else StoreConfig()
        self.clock = clock or Clock()
        self.cos = COS(self.clock, visibility_lag=cfg.cos_visibility_lag,
                       root=cos_root)
        self.sms = SMS(self.clock)
        self.window = SlidingWindow(cfg.gc, self.clock)
        self.codec = RSCodec(cfg.ec)
        self.mt = MetadataTable()
        self.pb = PersistentBuffer()
        self.logs: Dict[int, InsertionLog] = {}
        self.ledger = CostLedger()
        self.stats = StoreStats()
        self.rng = np.random.default_rng(seed)
        self._lock = threading.RLock()
        self.writeback = WritebackQueue(
            self.cos, max_depth=cfg.writeback_depth,
            max_retries=cfg.writeback_retries,
            backoff_base_s=cfg.writeback_backoff_s,
            start_thread=cfg.async_writeback)
        # chunk key -> function id (the daemon's chunk-function mapping)
        self.chunk_map: Dict[str, int] = {}
        # daemon's piggybacked view of each function's insertion state
        self.daemon_view: Dict[int, Piggyback] = {}
        from repro.core.sms import hardcap
        self.placement = PlacementManager(
            cfg.ec.n, hardcap(cfg.function_capacity),
            autoscale=cfg.autoscale,
            new_function_cb=self._on_new_function)
        self.recovery = RecoveryManager(
            self.sms, self.cos, self.logs,
            num_recovery_functions=cfg.num_recovery_functions,
            writeback=self.writeback)
        self._pending_records: Dict[int, List[PutRecord]] = {}
        # the client-daemon thread: every mutating request runs here, in
        # submission order — async callers pipeline, sync callers block
        self._daemon_ident: Optional[int] = None
        self._exec = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="store-client",
            initializer=self._register_daemon)

    # ------------------------------------------------------------------
    # async plumbing
    # ------------------------------------------------------------------

    def _register_daemon(self) -> None:
        self._daemon_ident = threading.get_ident()

    def _submit(self, fn) -> StoreFuture:
        fut = StoreFuture()
        if threading.get_ident() == self._daemon_ident:
            # re-entrant call from the daemon thread itself: run inline
            # (queueing would deadlock the single worker)
            try:
                fut._resolve(fn())
            except BaseException as e:            # noqa: BLE001
                fut.set_exception(e)
            return fut

        def run():
            try:
                fut._resolve(fn())
            except BaseException as e:            # noqa: BLE001
                fut.set_exception(e)
        self._exec.submit(run)
        return fut

    def flush_writeback(self, timeout: Optional[float] = None) -> bool:
        """Barrier: block until every acked PUT is persisted in COS.
        False on timeout or if any write failed out permanently (those
        payloads remain pinned in the persistent buffer)."""
        return self.writeback.flush(timeout=timeout)

    def close(self, *, flush: bool = True) -> bool:
        """Release the store's threads: drain the client-daemon executor
        FIRST (in-flight PUTs may still enqueue writebacks), then flush +
        stop the writeback writer. Returns False if writes were left
        unpersisted. The store must not be used afterwards."""
        self._exec.shutdown(wait=True)
        ok = self.writeback.close(flush=flush)
        self.cos.shutdown()
        return ok

    def cos_keys(self, prefix: str = "") -> List[str]:
        """COS key listing that includes acked-but-not-yet-persisted
        writes (the pending writeback map)."""
        keys = set(self.cos.list_keys(prefix))
        keys.update(self.writeback.pending_keys(prefix))
        return sorted(keys)

    # ------------------------------------------------------------------
    # function lifecycle
    # ------------------------------------------------------------------

    def _on_new_function(self, fid: int, fg_id: int, capacity: int) -> None:
        self.sms.add(fid, capacity)
        # with async writeback, log-node persistence rides the background
        # writer (the instance persists on return, §5.5.1 — not the
        # client's ack path); reads stay correct via the pending map
        self.logs[fid] = InsertionLog(
            fid, self.cos,
            writeback=self.writeback if self.cfg.async_writeback else None)
        self.daemon_view[fid] = Piggyback()
        self.window.latest.add_function(fid, fg_id)
        self.recovery.assign_group(fid, list(self.sms.slabs.keys()))

    def _invoke(self, fid: int, nbytes: int, category: str) -> None:
        """Invoke a function instance: failure detection happens here, on
        invocation, exactly as in the paper (§5.5.1)."""
        slab = self.sms.get(fid)
        busy = self.cfg.busy_base_s + nbytes * self.cfg.busy_per_byte_s
        was_dead = not slab.alive
        slab.invoke(busy)
        gb = slab.capacity / (1024 ** 3)
        self.ledger.invoke(category, gb=gb, seconds=busy)
        view = self.daemon_view.get(fid, Piggyback())
        failed = self.recovery.check_failed(slab, view) or was_dead
        if failed and view.term > 0 and self.cfg.enable_recovery:
            self._recover(fid)

    def _recover(self, fid: int) -> None:
        slab = self.sms.get(fid)
        view = self.daemon_view[fid]
        candidates = [f for f in self.sms.slabs
                      if self.window.state_of_function(f)
                      == BucketState.ACTIVE]
        t0 = self.clock.now()
        if self.recovery.needs_parallel(slab, view):
            session = self.recovery.recover_parallel(slab, candidates)
            nbytes = sum(len(v) for v in session.recovered.values())
            for rfid in session.group:
                self.ledger.invoke("recovery",
                                   gb=self.sms.get(rfid).capacity / 1024**3,
                                   seconds=self.cfg.busy_base_s
                                   + nbytes / max(len(session.group), 1)
                                   * self.cfg.busy_per_byte_s)
        else:
            n = self.recovery.recover_local(slab)
            self.ledger.invoke("recovery", gb=slab.capacity / 1024**3,
                               seconds=self.cfg.busy_base_s
                               + n * self.cfg.busy_per_byte_s * 1024)
        del t0

    # ------------------------------------------------------------------
    # PUT (Appendix A left + §5.3.1/§5.3.2)
    # ------------------------------------------------------------------

    def put(self, key: str, value) -> int:
        """Strongly-consistent versioned PUT (blocking wrapper over
        `put_async`). Returns the version."""
        return self.put_async(key, value).result()

    @staticmethod
    def _snapshot_value(value):
        """Snapshot mutable host buffers ON THE CALLER'S THREAD, at
        submission: once put_async returns, the caller may reuse its
        buffer — the store must already own a stable copy. bytes and
        device arrays are immutable and pass through zero-copy."""
        if needs_snapshot(value):
            return as_u8(value).copy()
        return value

    def put_async(self, key: str, value) -> StoreFuture:
        """Non-blocking PUT. The future resolves to the committed version
        once fragments land in SMS slabs + the persistent buffer; COS
        persistence continues in the background (see module docstring).
        The payload is captured at submission — the caller may mutate or
        reuse its buffer immediately."""
        value = self._snapshot_value(value)
        return self._submit(
            lambda: self._put_many_impl([(key, value)],
                                        raise_on_conflict=True)[key])

    def put_many(self, items, *, raise_on_conflict: bool = False
                 ) -> Dict[str, int]:
        """Batch PUT (blocking wrapper over `put_many_async`)."""
        return self.put_many_async(
            items, raise_on_conflict=raise_on_conflict).result()

    def put_many_async(self, items, *, raise_on_conflict: bool = False
                       ) -> StoreFuture:
        """Batch PUT: ONE leader-sequenced multi-key CAS round commits
        the whole batch's metadata, ALL fragments of ALL objects go
        through a single `encode_many` codec call, and chunk writes are
        grouped per function (one invoke + one insertion-log append
        each). items: dict or iterable of (key, value). The future
        resolves to {key: version} (-1 on failure), matching `put` per
        key. A CAS conflict on one key fails only that key (-1) unless
        raise_on_conflict (the single-key `put` contract: raise so the
        caller retries)."""
        items = list(items.items()) if isinstance(items, dict) \
            else list(items)
        items = [(k, self._snapshot_value(v)) for k, v in items]
        return self._submit(
            lambda: self._put_many_impl(items,
                                        raise_on_conflict=raise_on_conflict))

    def _put_many_impl(self, items, *, raise_on_conflict: bool = False
                       ) -> Dict[str, int]:
        if len({k for k, _ in items}) != len(items):
            # a duplicate key would CAS against its own in-flight version
            raise ValueError("duplicate keys in put_many batch")
        conflicted: List[str] = []
        installed: List[Tuple[str, object, object]] = []
        metas: List[Tuple[str, object, int, List[str]]] = []
        frags: List[Tuple[str, np.ndarray]] = []
        out: Dict[str, int] = {}
        try:
            cands = []
            for key, value in items:
                self.stats.puts += 1
                if is_array_payload(value):
                    self.stats.array_payload_puts += 1
                self._track_queue(payload_nbytes(value))
                cands.append((key, value, self.mt.prepare(key, 1)))
            # multi-key CAS: one metadata round per retry wave, not one
            # round per key
            pending = cands
            while pending:
                self.stats.cas_rounds += 1
                results = self.mt.cas_many([(k, c) for k, _, c in pending])
                nxt = []
                for (key, value, c), (m, ok) in zip(pending, results):
                    if ok:
                        installed.append((key, value, c))
                    elif not m.is_done():         # concurrent PUT in flight
                        m.wait(timeout=5.0)
                        if raise_on_conflict:
                            raise ConcurrentPutError(key)
                        conflicted.append(key)
                    else:
                        c.revise(m.ver + 1)
                        nxt.append((key, value, c))
                pending = nxt
            for key, value, c in installed:
                ver = c.ver
                self.mt.store(f"{key}|{ver}", c)
                # register for cleanup BEFORE fragmenting: once the CAS
                # installed c as the head, any failure below must still
                # finalize this key (fkeys is mutated in place)
                fkeys: List[str] = []
                metas.append((key, c, ver, fkeys))
                # mutable buffers were snapshotted at submission
                # (_snapshot_value), so this view is store-owned or
                # immutable-backed either way
                u8 = as_u8(value)
                fb = self.cfg.fragment_bytes
                fragments = [u8[i:i + fb]
                             for i in range(0, max(u8.size, 1), fb)]
                c.num_fragments = len(fragments)
                c.size = u8.size
                for fi, frag in enumerate(fragments):
                    fkey = f"{key}|{ver}/f{fi}"
                    # persistent buffer: one ref held by the PUT itself;
                    # each async chunk writeback retains another and
                    # releases it on persistence (§5.3.2 draining)
                    self.pb.create(fkey, frag)
                    fkeys.append(fkey)
                    frags.append((fkey, frag))
            failed = self._put_fragments(frags)
            # ACK POINT: chunks are in SMS slabs, fragments in the
            # persistent buffer, insertion logs appended. COS chunk
            # persistence drains asynchronously from the writeback queue;
            # the buffer entry lives until its last chunk persists.
            for key, c, ver, fkeys in metas:
                frag_failed = any(fk in failed for fk in fkeys)
                for fkey in fkeys:
                    if frag_failed:
                        self.pb.release_all(fkey)
                    else:
                        self.pb.release(fkey)     # drop the PUT's own ref
                ok = c.done(not frag_failed)
                if ok and c.prev_ver > 0:
                    self._gc_old_version(key, c.prev_ver)
                out[key] = ver if ok else -1
        except BaseException:
            # finalize every CAS-installed key that hasn't completed as
            # failed so no metadata head stays PENDING forever (readers
            # would block and later puts would raise on every attempt) —
            # covers CAS conflicts, encode/placement errors, MemoryError
            for _, c, _, fkeys in metas:
                if not c.is_done():
                    for fkey in fkeys:
                        self.pb.release_all(fkey)
                    c.done(False)
            for _, _, c in installed:
                if not c.is_done():               # installed, not fragmented
                    c.done(False)
            raise
        for key in conflicted:
            out[key] = -1
        return out

    def _gc_old_version(self, key: str, ver: int) -> None:
        """Free the superseded version's SMS chunks (COS retains them for
        any concurrent reader still on the old version)."""
        m = self.mt.load(f"{key}|{ver}")
        nfrags = m.num_fragments if m is not None else 1
        for fi in range(nfrags):
            for idx in range(self.cfg.ec.n):
                ckey = f"{key}|{ver}/f{fi}#{idx}"
                fid = self.chunk_map.pop(ckey, None)
                if fid is not None and fid in self.sms.slabs:
                    slab = self.sms.get(fid)
                    data = slab.load(ckey)
                    if slab.delete(ckey) and data is not None:
                        self.placement.release(fid, len(data))
                self.window.unmark(ckey)

    def _place_chunk(self, idx: int, nbytes: int) -> int:
        """PlaceChunk with the SLAB as the authority on fullness: if the
        placement ledger drifted (migrations/recovery add slab bytes it
        doesn't see), seal the FG to resync and probe on."""
        while True:
            fid = self.placement.place_chunk(idx, nbytes)
            slab = self.sms.get(fid)
            if slab.used < slab.hardcap:
                return fid
            self.placement.seal_fg(self.placement.functions[fid].fg_id)

    def _persist_chunk(self, fkey: str, ckey: str, chunk) -> None:
        """Route one chunk's COS persistence: inline on the ack path
        (legacy mode) or via the background writeback queue."""
        self.ledger.cos_op("put")
        if self.cfg.async_writeback:
            self.pb.retain(fkey)
            self.writeback.enqueue(f"chunk/{ckey}", chunk,
                                   on_done=self._on_chunk_persisted)
        else:
            self.cos.put(f"chunk/{ckey}", chunk)

    def _on_chunk_persisted(self, cos_key: str, ok: bool) -> None:
        """Writeback completion: drop the chunk's persistent-buffer ref.
        A write that exhausted its retries keeps the ref — the buffer
        stays the durable copy rather than silently losing data."""
        if ok:
            fkey = cos_key[len("chunk/"):].rsplit("#", 1)[0]
            self.pb.release(fkey)

    def _put_fragments(self, frags: List[Tuple[str, np.ndarray]]
                       ) -> Set[str]:
        """Encode ALL fragments in one `encode_many` call (array chunks:
        uint8 views into the stacked encode buffer, no bytes copies),
        place every chunk, then drain the writes grouped by target
        function: one `_invoke` covering the function's whole byte share
        (amortizing the per-request busy-time base of the billing model,
        §5.2) and one insertion-log append per function (§5.5.1).
        Returns the set of fragment keys whose chunks failed to store."""
        if not frags:
            return set()
        all_chunks = self.codec.encode_many([frag for _, frag in frags],
                                            as_arrays=True)
        groups: Dict[int, List[Tuple[str, str, object]]] = {}
        for (fkey, _), chunks in zip(frags, all_chunks):
            for idx, chunk in enumerate(chunks):
                ckey = f"{fkey}#{idx}"
                fid = self._place_chunk(idx, len(chunk))
                # compact the chunk out of the batch-wide stacked encode
                # buffer (one memcpy, as the legacy tobytes did) so a
                # long-lived slab/COS chunk never pins the whole batch
                groups.setdefault(fid, []).append((fkey, ckey,
                                                   chunk.copy()))
        # phase 1: slab writes only, so a fragment can still fail before
        # anything about it becomes durable
        failed: Set[str] = set()
        written: Dict[int, List[Tuple[str, str, object]]] = {}
        for fid, items in groups.items():
            slab = self.sms.get(fid)
            self._invoke(fid, sum(len(c) for _, _, c in items), "request")
            for fkey, ckey, chunk in items:
                tfid = fid
                stored = slab.store(ckey, chunk)
                if not stored:
                    # the slab refused what the ledger allowed: batch
                    # placement ran before any write, so _place_chunk's
                    # slab-authority resync (§5.3.1) never saw the bytes
                    # this batch already stored here. Release and
                    # re-place now that slab.used is live.
                    self.placement.release(tfid, len(chunk))
                    idx = int(ckey.rsplit("#", 1)[1])
                    for _ in range(3):
                        tfid = self._place_chunk(idx, len(chunk))
                        tslab = self.sms.get(tfid)
                        self._invoke(tfid, len(chunk), "request")
                        if tslab.store(ckey, chunk):
                            stored = True
                            break
                        self.placement.release(tfid, len(chunk))
                if stored:
                    written.setdefault(tfid, []).append((fkey, ckey, chunk))
                else:
                    failed.add(fkey)
        # phase 2: failed fragments roll their stored chunks back out of
        # the slabs; surviving fragments become visible (chunk_map), are
        # queued for COS persistence (§5.3.2), and land in the insertion
        # log — the durable point
        for fid, items in written.items():
            slab = self.sms.get(fid)
            records: List[PutRecord] = []
            for fkey, ckey, chunk in items:
                if fkey in failed:
                    if slab.delete(ckey):
                        self.placement.release(fid, len(chunk))
                    continue
                with self._lock:
                    self.chunk_map[ckey] = fid
                self._persist_chunk(fkey, ckey, chunk)
                records.append(PutRecord(key=ckey, size=len(chunk),
                                         version=0))
            # consolidate this window's records into insertion nodes
            if records:
                log = self.logs[fid]
                log.append(records)
                slab.term = log.term
                slab.log_hash = log.last_hash
                slab.diff_rank = log.diff_rank
                self.daemon_view[fid] = log.piggyback()
        return failed

    # ------------------------------------------------------------------
    # GET (Appendix A right + §5.3.3)
    # ------------------------------------------------------------------

    def get(self, key: str) -> Optional[bytes]:
        return self.get_async(key).result()

    def get_async(self, key: str) -> StoreFuture:
        """Non-blocking GET; the future resolves to bytes or None."""
        return self._submit(lambda: self._get_many_impl([key])[key])

    def get_many(self, keys) -> Dict[str, Optional[bytes]]:
        return self.get_many_async(keys).result()

    def get_many_async(self, keys) -> StoreFuture:
        """Batch GET: chunk reads are grouped into ONE invoke per function
        across the whole gather, and ALL fragments needing EC
        reconstruction are decoded by a single `decode_many` call. The
        future resolves to {key: value-or-None}."""
        keys = list(keys)
        return self._submit(lambda: self._get_many_impl(keys))

    def get_array(self, key: str) -> Optional[np.ndarray]:
        """GET returning a flat uint8 array (no bytes materialization) —
        the device/checkpoint payload path."""
        return self.get_many_arrays([key])[key]

    def get_many_arrays(self, keys) -> Dict[str, Optional[np.ndarray]]:
        return self.get_many_arrays_async(keys).result()

    def get_many_arrays_async(self, keys) -> StoreFuture:
        keys = list(keys)
        return self._submit(
            lambda: self._get_many_impl(keys, as_arrays=True))

    def _get_many_impl(self, keys, *, as_arrays: bool = False) -> Dict:
        out: Dict = {}
        plans: List[Tuple[str, object, List[object]]] = []
        gather_fkeys: List[str] = []
        for key in dict.fromkeys(keys):    # dedup, keep first-seen order
            self.stats.gets += 1
            m = self._resolve_meta(key)
            if m is None:
                out[key] = None
                continue
            parts: List[object] = []   # payload, or str fkey placeholder
            for fi in range(m.num_fragments):
                fkey = f"{key}|{m.ver}/f{fi}"
                buf = self.pb.load(fkey)             # read-after-write
                if buf is not None:
                    self.stats.buffer_hits += 1
                    parts.append(buf)
                else:
                    parts.append(fkey)
                    gather_fkeys.append(fkey)
            plans.append((key, m, parts))
        gathered = self._gather_many(gather_fkeys) if gather_fkeys else {}
        batch: List[Dict[int, object]] = []
        final: List[Tuple[str, object, List[object]]] = []
        for key, m, parts in plans:
            resolved: List[object] = []
            for p in parts:
                if isinstance(p, str):               # needs chunk gather
                    chunks = gathered.get(p)
                    if chunks is None:
                        out[key] = None
                        resolved = None
                        break
                    resolved.append(len(batch))
                    batch.append(chunks)
                else:
                    resolved.append(p)
            if resolved is not None:
                # only successful keys reach the decode batch; a failed
                # key's already-gathered fragments are dropped here
                final.append((key, m, resolved))
        decoded = self.codec.decode_many(batch, as_arrays=as_arrays) \
            if batch else []
        for key, m, parts in final:
            pieces = [decoded[p] if isinstance(p, int) else p
                      for p in parts]
            val = self._assemble(pieces, m.size, as_arrays)
            self._track_queue(payload_nbytes(val))
            out[key] = val
        return out

    @staticmethod
    def _assemble(pieces: List[object], size: int, as_arrays: bool):
        """Join fragment payloads into the object value, trimmed to the
        metadata size. Array results are READ-ONLY views: a single-
        fragment result can alias the persistent buffer's durable copy,
        and stored objects are immutable by contract anyway."""
        if as_arrays:
            val = pieces[0] if len(pieces) == 1 else \
                np.concatenate([as_u8(p) for p in pieces])
            val = as_u8(val)
            out = (val[:size] if size else val).view()
            out.flags.writeable = False
            return out
        if all(isinstance(p, bytes) for p in pieces):
            val = b"".join(pieces)
        else:
            val = b"".join(to_bytes(p) for p in pieces)
        return val[:size] if size else val

    def _resolve_meta(self, key: str):
        """Follow the version chain to the newest done-ok metadata."""
        m = self.mt.load(key)
        attempts = 0
        while m is not None and not m.is_done_ok() and attempts < 8:
            if not m.is_done():                       # concurrent PUT
                m.wait(timeout=5.0)
            if m.is_done_ok():
                break
            if m.prev_ver <= 0:
                return None
            m = self.mt.load(f"{key}|{m.prev_ver}")
            attempts += 1
        if m is None or not m.is_done_ok():
            return None
        return m

    def _gather_many(self, fkeys: Sequence[str]
                     ) -> Dict[str, Optional[Dict[int, object]]]:
        """Gather >= k chunks for every fragment, issuing AT MOST ONE
        invoke per function across the whole gather (the GET-side mirror
        of the PUT-side per-function grouping)."""
        n, k = self.cfg.ec.n, self.cfg.ec.k
        have: Dict[str, Dict[int, object]] = {f: {} for f in fkeys}
        candidates: Dict[str, List[Tuple[int, str, int]]] = {}
        for fkey in fkeys:
            cand = []
            for idx in range(n):
                ckey = f"{fkey}#{idx}"
                fid = self.chunk_map.get(ckey)
                if fid is not None:
                    cand.append((idx, ckey, fid))
            candidates[fkey] = cand
        # round 0 reads the first k mapped chunks per fragment (EC needs
        # only k); round 1 widens to the remaining mapped chunks for
        # fragments a failed read left short. Each round groups reads by
        # function: one invoke covers every chunk the function serves.
        tried: Set[Tuple[str, int]] = set()
        invoked: Set[int] = set()
        for rnd in (0, 1):
            groups: Dict[int, List[Tuple[str, int, str]]] = {}
            for fkey, cand in candidates.items():
                short = k - len(have[fkey])
                if short <= 0:
                    continue
                sel = cand[:k] if rnd == 0 else cand
                for idx, ckey, fid in sel:
                    if (fkey, idx) in tried or idx in have[fkey]:
                        continue
                    tried.add((fkey, idx))
                    groups.setdefault(fid, []).append((fkey, idx, ckey))
            if not groups:
                continue
            degraded: List[str] = []
            for fid, group in groups.items():
                for fkey, idx, data in self._read_chunks_grouped(
                        fid, group, degraded, invoked):
                    have[fkey][idx] = data
            if degraded:
                self._migrate_chunks(degraded)        # sync migration
        out: Dict[str, Optional[Dict[int, object]]] = {}
        for fkey, got in have.items():
            if len(got) < k:
                # on-demand migration from COS (§5.3.3); the pending
                # writeback map covers acked-but-unpersisted chunks
                for idx in range(n):
                    if idx in got:
                        continue
                    ckey = f"{fkey}#{idx}"
                    data = self._cos_read_consistent(f"chunk/{ckey}")
                    if data is not None:
                        got[idx] = data
                        self._demand_cache(ckey, data)
                    if len(got) >= k:
                        break
            out[fkey] = got if len(got) >= k else None
        return out

    def _read_chunks_grouped(self, fid: int,
                             items: List[Tuple[str, int, str]],
                             degraded_out: List[str],
                             invoked: Set[int]) -> List[Tuple[str, int, object]]:
        """Read this function's share of a gather with ONE invoke (and
        one consolidated ledger charge for the bytes served)."""
        out: List[Tuple[str, int, object]] = []
        slab = self.sms.slabs.get(fid)
        if slab is None:                              # function released
            self.stats.sms_chunk_misses += len(items)
            return out
        state = self.window.state_of_function(fid)
        if state is None or state == BucketState.RELEASED:
            self.stats.sms_chunk_misses += len(items)
            return out
        if fid not in invoked:
            self._invoke(fid, 0, "request")
            self.stats.gather_invokes += 1
            invoked.add(fid)
        nbytes = 0
        for fkey, idx, ckey in items:
            data = self.recovery.serve_during_recovery(fid, ckey)
            if data is None:
                data = slab.load(ckey)
            if data is None:
                self.stats.sms_chunk_misses += 1
                continue
            self.stats.sms_chunk_hits += 1
            nbytes += len(data)
            # mark re-accessed data for compaction (§5.3.3)
            self.window.mark(ckey)
            if state == BucketState.DEGRADED:
                self.stats.degraded_hits += 1
                degraded_out.append(ckey)
            out.append((fkey, idx, data))
        if nbytes:
            self.ledger.invoke("request", gb=slab.capacity / 1024**3,
                               seconds=nbytes * self.cfg.busy_per_byte_s)
        return out

    def _cos_read_consistent(self, key: str, max_tries: int = 16):
        """SCFS-style consistency-increasing loop: retry until the
        eventually-consistent COS shows the object (Appendix A). Writes
        still queued for persistence are served from the writeback
        pending map — they're not in COS yet by construction."""
        for _ in range(max_tries):
            data = self.writeback.peek(key)
            if data is not None:
                return data
            data = self.cos.get(key)
            self.ledger.cos_op("get")
            if data is not None:
                return data
            if self.clock.is_wall:
                import time
                time.sleep(0.005)
            else:
                self.clock.advance(max(self.cfg.cos_visibility_lag / 4,
                                       0.001))
        return None

    # ------------------------------------------------------------------
    # demand caching + compaction + GC
    # ------------------------------------------------------------------

    def _demand_cache(self, ckey: str, data) -> None:
        """GET-triggered caching into the latest bucket's cache space
    (§5.3.3 'cache functions'); evictable, not counted against HARDCAP."""
        fid = self.placement.get_open_funcs(0)[0]
        self.sms.get(fid).cache_put(ckey, data)
        with self._lock:
            self.chunk_map[ckey] = fid
        self.stats.migrations += 1

    def _migrate_chunks(self, ckeys: List[str]) -> None:
        """Compaction: move marked/hit chunks into the latest GC-bucket by
        loading them from COS into newly placed slots (§5.3.3)."""
        for ckey in ckeys:
            data = self.writeback.peek(f"chunk/{ckey}")
            if data is None:
                data = self.cos.get(f"chunk/{ckey}")
                self.ledger.cos_op("get")
            if data is None:
                old = self.chunk_map.get(ckey)
                data = self.sms.slabs[old].load(ckey) if old is not None \
                    and old in self.sms.slabs else None
            if data is None:
                continue
            idx = int(ckey.rsplit("#", 1)[1])
            fid = self._place_chunk(idx, len(data))
            slab = self.sms.get(fid)
            self._invoke(fid, len(data), "request")
            if slab.store(ckey, data):
                old = self.chunk_map.get(ckey)
                with self._lock:
                    self.chunk_map[ckey] = fid
                if old is not None and old != fid and old in self.sms.slabs:
                    self.sms.get(old).delete(ckey)
                    self.placement.release(old, len(data))
                log = self.logs[fid]
                log.append([PutRecord(key=ckey, size=len(data), version=0)])
                slab.term, slab.log_hash, slab.diff_rank = \
                    log.term, log.last_hash, log.diff_rank
                self.daemon_view[fid] = log.piggyback()
                self.window.unmark(ckey)
                self.stats.compactions += 1

    def gc_tick(self) -> None:
        """Run due GC + one compaction round + warmups + a writeback
        drain slice. Call periodically (the serving engine ticks this;
        tests drive the clock). Runs on the client-daemon thread so it
        serializes with in-flight async PUT/GETs."""
        self._submit(self._gc_tick_impl).result()

    def _gc_tick_impl(self) -> None:
        if self.window.due():
            ev = self.window.run_gc()
            # carry open FGs into the new bucket (Fig. 4c)
            for fg_id in self.placement.carry_over_open_fgs():
                for fid in self.placement.fgs[fg_id].fids:
                    ev.new_bucket.add_function(fid, fg_id)
            for fid in ev.released_functions:
                slab = self.sms.slabs.get(fid)
                if slab is not None:
                    slab.reclaim()                    # provider reclaims
        round_keys = self.window.take_compaction_round(self.rng)
        if round_keys:
            self._migrate_chunks(round_keys)
        self._warmup_tick()
        if self.cfg.async_writeback:
            self.writeback.drain(32)                  # §5.3.2 retry point
        # provider-side reclamation of long-idle instances
        self.sms.reclaim_idle(self.cfg.provider_idle_reclaim)

    def _warmup_tick(self) -> None:
        """No-op heartbeat per FMP: active buckets every active_warmup,
        degraded every degraded_warmup (§5.3)."""
        now = self.clock.now()
        for fid, slab in self.sms.slabs.items():
            period = self.window.warmup_period(fid)
            if period is None or not slab.alive:
                continue
            if now - slab.last_invoked >= period:
                slab.invoke(0.001)
                self.ledger.invoke("warmup", gb=slab.capacity / 1024**3,
                                   seconds=0.001)

    # ------------------------------------------------------------------
    # misc
    # ------------------------------------------------------------------

    def _track_queue(self, nbytes: int) -> None:
        if nbytes <= self.cfg.small_request_bytes:
            self.stats.small_requests += 1
        else:
            self.stats.large_requests += 1

    def inject_failure(self, fid: int) -> None:
        """Simulate provider reclaiming an instance (tests/benchmarks)."""
        self.sms.get(fid).reclaim()

    def num_functions(self, state: Optional[BucketState] = None) -> int:
        if state is None:
            return len(self.sms.slabs)
        return sum(len(b.function_ids)
                   for b in self.window.buckets(state))

    def snapshot_metadata(self):
        return {"mt": self.mt.snapshot(),
                "chunk_map": dict(self.chunk_map)}


class ConcurrentPutError(RuntimeError):
    def __init__(self, key: str):
        super().__init__(f"concurrent PUT in flight for {key!r}; retry")
