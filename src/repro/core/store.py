"""InfiniStore facade: an async, futures-based GET/PUT client API over
the SMS + COS layers (paper §5).

The client surface is non-blocking: `put_async` / `get_async` (and the
batched `put_many_async` / `get_many_async`) return a `StoreFuture`
(result / exception / done-callback; PUT futures carry the committed
version). The classic `put` / `get` / `put_many` / `get_many` are thin
blocking wrappers over the same path. All store mutation runs on one
internal client-daemon thread, so queued requests pipeline in submission
order and the data structures never see concurrent writers.

Ack point + durability contract (§5.3.2): a PUT acknowledges once every
fragment's chunks sit in SMS slabs AND the fragment sits in the
persistent buffer with its insertion-log node persisted — COS chunk
persistence is OFF the critical path, drained in the background by the
`WritebackQueue` (writer thread + `gc_tick`, bounded depth, retry with
backoff, `flush()` barrier). Until a chunk lands in COS, reads and
recovery are served from the persistent buffer / pending-writeback map,
so an instance failure between ack and persistence loses nothing.
`StoreConfig(async_writeback=False)` restores the legacy inline-COS ack
path (the benchmark baseline).

The durability contract also survives the DAEMON: every enqueued write
(and each PUT's committed metadata) is appended to a crash-consistent
local spill journal (`repro.core.spill`) before the ack, and a store
rebuilt on the same `StoreConfig(spill_dir=...)` replays surviving
records on construction — metadata is restored, pending writes re-enter
the queue, and post-restart GETs / instance recovery serve them exactly
like live pending data. `spill_dir=None` restores the memory-only
pending map; `simulate_crash()` is the kill half of the kill/restart
tests.

Payloads follow the `Payload` protocol: `bytes`, numpy arrays, or
device-backed `jax.Array`s are fragmented as flat uint8 views and reach
the bit-sliced GF(256) kernel without an intermediate `bytes` copy;
`get_array` / `get_many_arrays` return uint8 arrays the same way.

GET is a pipeline (§5.3.3 + readahead): one grouped SMS sweep per batch
(at most one invoke per function), then every still-short fragment's
missing chunks fan out to COS concurrently on a bounded I/O executor
while fragments decode in ready-order `decode_many` batches — decode of
fragment A overlaps the gather of fragment B. Degraded-bucket compaction
migrates from `gc_tick`, off the read critical path. A sequential-scan
prefetcher (`repro.core.prefetch`) watches the object-key stream and
warms the predicted next objects' chunks into bucket cache space during
decode (checkpoint shard restore and KV page restore both scan ordered
trailing-index keys). `StoreConfig(pipelined_get=False)` restores the
legacy serial gather -> barrier -> decode path for A/B comparison.

Also wired through: CAS versioning with multi-key batch commit (one
leader-sequenced metadata round per `put_many`), RS erasure coding,
PlaceChunk over the sliding-window GC-buckets, insertion logs, failure
detection + local/parallel recovery, demand caching, compaction,
large-object fragmentation, grouped per-function invokes on BOTH the
PUT and GET data paths, the two-queue scheme, and pay-per-access cost
accounting.
"""
from __future__ import annotations

import itertools
import json
import logging
import os
import shutil
import tempfile
import threading
import time
from concurrent.futures import (FIRST_COMPLETED, Future,
                                ThreadPoolExecutor, wait)
from dataclasses import dataclass, field
from typing import (Dict, List, Optional, Protocol, Sequence, Set, Tuple,
                    runtime_checkable)

import numpy as np

from repro.core.clock import Clock
from repro.core.cos import COS
from repro.core.costmodel import CostLedger
from repro.core.ec import ECConfig, RSCodec
from repro.core.faults import (FaultPlan, OpDeadlineExceeded, RetryPolicy)
from repro.core.gc_window import BucketState, GCConfig, SlidingWindow
from repro.core.insertion_log import InsertionLog, Piggyback, PutRecord
from repro.core.locks import make_rlock
from repro.core.payload import (as_u8, is_array_payload, needs_snapshot,
                                payload_nbytes, to_bytes)
from repro.core.placement import PlacementManager
from repro.core.prefetch import PrefetchConfig, SequentialPrefetcher
from repro.core.recovery import RecoveryManager
from repro.core.sms import SMS
from repro.core.spill import SpillJournal
from repro.core.versioning import Meta, MetadataTable, PersistentBuffer
from repro.core.writeback import StoreFuture, WritebackQueue
from repro.obs import NOOP_CM, ObsPlane, to_prometheus
from repro.obs.metrics import dump_json

MB = 1024 * 1024

_LOG = logging.getLogger("repro.core.store")

# sentinel seq for a metadata record whose durable copy lives inside the
# journal's `metasnap` snapshot rather than an individual `meta/` frame
_SNAP_COVERED = -1


@dataclass
class StoreConfig:
    ec: ECConfig = field(default_factory=ECConfig)       # RS(10+2)
    function_capacity: int = 1536 * MB                   # Lambda memory
    fragment_bytes: int = 200 * MB                       # §5.3.4
    small_request_bytes: int = 1 * MB                    # two-queue split
    gc: GCConfig = field(default_factory=GCConfig)
    num_recovery_functions: int = 20
    enable_recovery: bool = True       # False = SNR ablation (Fig. 22/23)
    provider_idle_reclaim: float = 3600.0                # FaaS reclamation
    cos_visibility_lag: float = 0.0
    autoscale: str = "linear"
    # estimated per-request function busy time model (seconds/byte + base),
    # calibrated to the paper's ~75 MB/s per-instance bandwidth
    busy_base_s: float = 0.001
    busy_per_byte_s: float = 1.0 / (75 * MB)
    # ---- async writeback (§5.3.2) --------------------------------------
    # True: PUT acks after SMS slabs + persistent buffer + insertion log;
    # COS chunk writes drain in the background. False: legacy inline COS
    # writes on the ack path (benchmark baseline / strict-persist mode).
    async_writeback: bool = True
    writeback_depth: int = 512         # queue bound (backpressure)
    writeback_retries: int = 8
    writeback_backoff_s: float = 0.005
    # consecutive transient COS failures before the writeback queue
    # declares an outage and enters DEGRADED_WRITEBACK (retry budgets
    # freeze, producers feel backpressure, reads keep serving from the
    # pending map / spill journal; see repro.core.writeback)
    writeback_degraded_after: int = 12
    # ---- unified retry policy (repro.core.faults) ----------------------
    # demand COS reads retry transient/throttle errors and eventual-
    # consistency misses up to cos_retries attempts; an optional per-op
    # deadline turns an exhausted budget into OpDeadlineExceeded
    # surfaced through the GET's StoreFuture instead of a silent miss
    cos_retries: int = 16
    cos_op_deadline_s: Optional[float] = None
    # ---- deterministic fault injection (repro.core.faults) -------------
    # an optional FaultPlan threaded through COS, SMS slabs, the spill
    # journal, and the writeback writer; None (default) keeps every
    # instrumented site a single attribute check
    faults: Optional[FaultPlan] = None
    # ---- observability plane (repro.obs) -------------------------------
    # an optional ObsPlane threaded through the same layers as `faults`
    # (client daemon, writeback writer, GET I/O executor, spill journal,
    # and across the shard transports so worker-process spans stitch
    # into the frontend's trace); None (default) keeps every
    # instrumented site a single attribute check
    obs: Optional[ObsPlane] = None
    # ---- crash-consistent writeback spill (§5.3.2 durability) ----------
    # The durable half of the persistent buffer: enqueued writes are
    # journaled to an append-only, CRC-framed, segment-rotated local log
    # BEFORE the PUT acks, and replayed into the queue when a store is
    # rebuilt on the same directory after a daemon crash/restart.
    # "auto" = private tempdir (journaling on, restart resume opted out);
    # a path = durable across restarts; None = the pre-journal in-memory
    # pending map (A/B baseline). Only meaningful with async_writeback.
    spill_dir: Optional[str] = "auto"
    spill_segment_bytes: int = 64 * MB
    spill_fsync: bool = False          # True: machine-crash durability
    # size-bounded metadata log: once this many superseded-able metadata
    # records (individual `meta/` frames + tombstones) accumulate in the
    # journal, gc_tick snapshots the whole journaled metadata table into
    # ONE `metasnap` record at a fresh journal generation (forced
    # segment rotation) and truncates everything the snapshot covers —
    # replay work for a long-lived daemon is capped at one snapshot plus
    # the post-snapshot tail instead of growing with PUT history. 0
    # disables snapshotting (the PR-4 retain-until-superseded baseline).
    spill_meta_snapshot_records: int = 1024
    # temporary recovery placements (cache_put into the recovery group,
    # §5.5.2) expire this many seconds after the session completes
    recovery_retain_seconds: float = 60.0
    # ---- pipelined GET (§5.3.3 + readahead) ----------------------------
    # True: grouped SMS reads, then COS demand reads fan out concurrently
    # on a bounded I/O executor while fragments decode in ready-order
    # batches; compaction migration drains from gc_tick. False: the
    # legacy serial gather -> barrier -> decode path (A/B baseline).
    pipelined_get: bool = True
    get_io_workers: int = 8            # COS fallback / prefetch fan-out
    decode_batch_fragments: int = 4    # fragments per ready-order decode
    # sequential-scan readahead: after `prefetch_min_run` consecutive
    # trailing-index keys, the next `prefetch_depth` objects' missing
    # chunks are warmed into bucket cache space during decode
    prefetch: bool = True
    prefetch_min_run: int = 3
    prefetch_depth: int = 2
    prefetch_max_inflight: int = 64    # warm fetches in flight at once


class AtomicCounter:
    """Lock-free monotonic counter that is safe under concurrent
    writers in CPython: `add` advances an `itertools.count` — each step
    is one C call, atomic under the GIL, so increments from any number
    of threads never lose updates — and `value` snapshots the iterator
    state via `__reduce__` (also a single C call) without consuming a
    tick."""
    __slots__ = ("_c",)

    def __init__(self, start: int = 0):
        self._c = itertools.count(start)

    def add(self, n: int = 1) -> None:
        if n == 1:
            next(self._c)
        else:
            # n is small (chunks per fragment / items per sweep); each
            # step is individually atomic, so concurrent adders
            # interleave without losing increments
            for _ in range(n):
                next(self._c)

    @property
    def value(self) -> int:
        return self._c.__reduce__()[1][0]


class _Stat:
    """Counter field of `StoreStats`: reads return the plain int value;
    assignment RESEEDS the counter (single-writer sites only — the
    prefetch mirror and stats aggregation)."""
    __slots__ = ("attr",)

    def __set_name__(self, owner, name) -> None:
        self.attr = "_" + name

    def __get__(self, obj, objtype=None):
        if obj is None:
            return self
        return getattr(obj, self.attr).value

    def __set__(self, obj, value) -> None:
        setattr(obj, self.attr, AtomicCounter(int(value)))


_STAT_FIELDS = (
    "puts",
    "gets",
    "sms_chunk_hits",
    "sms_chunk_misses",
    "buffer_hits",
    "migrations",
    "compactions",
    "degraded_hits",
    "small_requests",
    "large_requests",
    "cas_rounds",             # multi-key CAS: metadata rounds issued
    "gather_invokes",         # GET-side grouped per-function invokes
    "array_payload_puts",     # PUTs that arrived as array payloads
    "prefetch_hits",          # warmed chunks consumed by a GET
    "prefetch_wasted",        # warmed chunks dropped unconsumed
    "cos_fallback_reads",     # demand chunk reads sent to COS
    "decode_batches",         # ready-order decode_many calls
    "spill_replayed_writes",  # journal records re-enqueued at open
    "spill_replayed_metas",   # metadata records restored at open
    "spill_meta_snapshots",   # metadata-table snapshots journaled
    "commit_tickets",         # leader-sequenced cross-shard commits
    "writeback_permanent_failures",   # mirror of queue data-at-risk count
    "indoubt_resolved",       # prepared 2PC batches rolled forward/back
)


class StoreStats:
    """Store counters, every field an `AtomicCounter`.

    Consistency model: each counter is individually atomic and
    monotonic — increments come from the client-daemon thread, the
    writeback writer, and GET I/O workers WITHOUT the store lock, and
    none are lost. Reads (attribute access, `snapshot_metadata()`, the
    sharded aggregation) are per-counter atomic but NOT a consistent
    cut across counters: a reader racing a PUT may observe `puts`
    already bumped while `cas_rounds` is not yet. Derived ratios are
    therefore approximate while traffic is in flight and exact once the
    store is quiescent."""

    for _f in _STAT_FIELDS:
        locals()[_f] = _Stat()
    del _f

    def __init__(self, **kw):
        for f in _STAT_FIELDS:
            setattr(self, f, kw.pop(f, 0))
        if kw:
            raise TypeError(f"unknown StoreStats fields: {sorted(kw)}")

    def inc(self, name: str, n: int = 1) -> None:
        """Atomically add `n` to one counter (lock-free, multi-writer
        safe — see the class docstring)."""
        getattr(self, "_" + name).add(n)

    def as_dict(self) -> Dict[str, int]:
        return {f: getattr(self, f) for f in _STAT_FIELDS}

    def __repr__(self) -> str:
        body = ", ".join(f"{f}={getattr(self, f)}" for f in _STAT_FIELDS)
        return f"StoreStats({body})"

    @property
    def hit_ratio(self) -> float:
        return self.derived(self.as_dict())["hit_ratio"]

    @staticmethod
    def derived(snap: Dict[str, int]) -> Dict[str, float]:
        """Ratios computed from ONE `as_dict()` snapshot, so each
        numerator/denominator pair comes from the same read pass.
        Reading the live counters once per ratio (the old pattern) let
        in-flight traffic skew a ratio's own terms against each other;
        a single snapshot keeps every reported ratio internally
        consistent (still approximate vs other counters — see the class
        docstring's consistency model)."""
        hits, misses = snap["sms_chunk_hits"], snap["sms_chunk_misses"]
        tot = hits + misses
        warmed = snap["prefetch_hits"] + snap["prefetch_wasted"]
        gets = snap["gets"]
        return {"hit_ratio": hits / tot if tot else 0.0,
                "prefetch_efficiency":
                    snap["prefetch_hits"] / warmed if warmed else 0.0,
                "cos_fallback_per_get":
                    snap["cos_fallback_reads"] / gets if gets else 0.0,
                "decode_batches_per_get":
                    snap["decode_batches"] / gets if gets else 0.0}


@dataclass
class _PreparedBatch:
    """Round-1 state of a (possibly cross-shard) PUT batch: everything
    `_put_many_prepare` installed, for `_put_many_commit` to finalize or
    `_put_many_abort` to roll back. Opaque to callers."""
    raise_on_conflict: bool = False
    conflicted: List[str] = field(default_factory=list)
    # (key, value, candidate Meta) CAS-installed as PENDING heads
    installed: List[Tuple[str, object, object]] = field(default_factory=list)
    # (key, candidate Meta, version, fragment keys)
    metas: List[Tuple[str, object, int, List[str]]] = \
        field(default_factory=list)
    failed: Set[str] = field(default_factory=set)  # fragments that failed
    resolved: bool = False            # committed or aborted
    # cross-shard batches only: the leader ticket this batch was prepared
    # under, and the journal seq of its durable `prepared/<ticket>`
    # record (truncated when the batch resolves)
    ticket: Optional[int] = None
    prepared_seq: Optional[int] = None
    # objs ("key|ver") whose commit-side finalization fully ran — a
    # RETRIED ticketed commit (in-doubt roll-forward after a journal
    # error) skips them instead of double-releasing buffer refs
    committed: Set[str] = field(default_factory=set)


@runtime_checkable
class StoreFrontend(Protocol):
    """The client-facing store surface shared by the singleton
    `InfiniStore` and the keyspace-partitioned `ShardedStore`
    (`repro.core.shard`). Anything program-level — checkpointing, KV
    eviction, benchmarks — should accept this protocol rather than a
    concrete store so it runs unchanged on one daemon or many."""

    def put(self, key: str, value) -> int: ...
    def put_async(self, key: str, value) -> StoreFuture: ...
    def put_many(self, items, *, raise_on_conflict: bool = False
                 ) -> Dict[str, int]: ...
    def put_many_async(self, items, *, raise_on_conflict: bool = False
                       ) -> StoreFuture: ...
    def get(self, key: str) -> Optional[bytes]: ...
    def get_async(self, key: str) -> StoreFuture: ...
    def get_many(self, keys) -> Dict[str, Optional[bytes]]: ...
    def get_many_async(self, keys) -> StoreFuture: ...
    def get_array(self, key: str) -> Optional[np.ndarray]: ...
    def get_many_arrays(self, keys) -> Dict[str, Optional[np.ndarray]]: ...
    def get_many_arrays_async(self, keys) -> StoreFuture: ...
    def flush_writeback(self, timeout: Optional[float] = None) -> bool: ...
    def close(self, *, flush: bool = True) -> bool: ...
    def gc_tick(self) -> None: ...
    def cos_keys(self, prefix: str = "") -> List[str]: ...
    def snapshot_metadata(self): ...
    def snapshot_metrics(self) -> Dict: ...


class InfiniStore:
    def __init__(self, cfg: Optional[StoreConfig] = None, *,
                 clock: Optional[Clock] = None,
                 cos_root: Optional[str] = None, seed: int = 0,
                 cos: Optional[COS] = None, name: str = ""):
        # NOTE: cfg default must be constructed per-instance — a dataclass
        # default in the signature would be shared (and cross-mutated)
        # between every default-constructed store.
        self.cfg = cfg = cfg if cfg is not None else StoreConfig()
        self.clock = clock or Clock()
        # `name` tags this store's threads (and nothing else) so a
        # multi-shard deployment is debuggable; `cos` shares one COS
        # backend between shards — a store that did not construct its
        # COS must not shut it down either (the front-end owns it)
        self.name = name
        tag = f"-{name}" if name else ""
        self._owns_cos = cos is None
        self.cos = cos if cos is not None else \
            COS(self.clock, visibility_lag=cfg.cos_visibility_lag,
                root=cos_root)
        if cfg.faults is not None and self._owns_cos:
            # a shared (front-end-owned) COS gets its plan from the
            # front-end, not from each shard
            self.cos.faults = cfg.faults
        self.sms = SMS(self.clock)
        self.sms.faults = cfg.faults
        # unified transient/throttle retry policy for demand COS reads
        # (also handed to the recovery manager's chunk downloads)
        self.cos_retry = RetryPolicy(
            max_attempts=max(1, cfg.cos_retries),
            backoff_base_s=max(cfg.cos_visibility_lag / 8.0, 1e-3),
            backoff_cap_s=max(cfg.cos_visibility_lag, 0.05),
            seed=seed)
        self.window = SlidingWindow(cfg.gc, self.clock)
        self.codec = RSCodec(cfg.ec)
        self.mt = MetadataTable()
        self.pb = PersistentBuffer()
        self.logs: Dict[int, InsertionLog] = {}
        self.ledger = CostLedger()
        self.stats = StoreStats()
        self.rng = np.random.default_rng(seed)
        self._lock = make_rlock("store.InfiniStore._lock")
        # observability plane (repro.obs): threaded through the same
        # layers as `faults`. ISTORE_METRICS_DUMP=<path> auto-attaches
        # an enabled plane so the atexit Prometheus dump has a source
        # even when the caller configured none.
        if cfg.obs is None and os.environ.get("ISTORE_METRICS_DUMP"):
            cfg.obs = ObsPlane(name=name or "store")
        self._obs = cfg.obs
        if cfg.faults is not None and self._obs is not None:
            # mirror fault-plane fires into the flight recorder
            cfg.faults.obs = self._obs
        # crash-consistent spill journal (§5.3.2): the writeback queue
        # appends every enqueue here before the PUT acks; metadata
        # records ("meta/<key>|<ver>") journal the table entry so a
        # restarted daemon can serve replayed pending data. Journal seq
        # of each live object version's metadata record, truncated when
        # the version is superseded or the PUT aborts:
        self._spill_meta_seqs: Dict[str, int] = {}
        # metadata-snapshot generation state (size-bounded replay): the
        # live `metadrop/` tombstone seqs the NEXT snapshot truncates.
        # (The snapshot record itself needs no tracked seq — `metasnap`
        # is a constant key, so the journal's same-key supersession
        # retires the previous snapshot on every new append.)
        self._spill_tombstones: List[int] = []
        self.spill: Optional[SpillJournal] = None
        self._spill_auto = False
        spill_dir = cfg.spill_dir
        if cfg.async_writeback and spill_dir is not None:
            if spill_dir == "auto":
                spill_dir = tempfile.mkdtemp(prefix="infinistore-spill-")
                self._spill_auto = True
            # group-commit mode: enqueues buffer their journal frames;
            # the PUT path syncs ONCE at its ack point (one flush per
            # PUT, not one per chunk record)
            self.spill = SpillJournal(
                spill_dir, segment_bytes=cfg.spill_segment_bytes,
                fsync=cfg.spill_fsync, sync_each=False,
                faults=cfg.faults)
            self.spill.obs = self._obs
        self.spill_dir = spill_dir if self.spill is not None else None
        if self._obs is not None and self.spill_dir is not None:
            # one flight file per crash domain (= process): first bind
            # wins, so a worker process binds its shard directory here
            # while thread shards under a ShardedStore no-op (the
            # front-end bound the root's file before building shards)
            self._obs.bind_flight(
                os.path.join(self.spill_dir, "flight.bin"))
        self.writeback = WritebackQueue(
            self.cos, max_depth=cfg.writeback_depth,
            max_retries=cfg.writeback_retries,
            backoff_base_s=cfg.writeback_backoff_s,
            start_thread=cfg.async_writeback,
            spill=self.spill,
            name=f"cos-writeback{tag}",
            degraded_after=cfg.writeback_degraded_after,
            faults=cfg.faults, obs=self._obs)
        # chunk key -> function id (the daemon's chunk-function mapping)
        self.chunk_map: Dict[str, int] = {}
        # daemon's piggybacked view of each function's insertion state
        self.daemon_view: Dict[int, Piggyback] = {}
        from repro.core.sms import hardcap
        self.placement = PlacementManager(
            cfg.ec.n, hardcap(cfg.function_capacity),
            autoscale=cfg.autoscale,
            new_function_cb=self._on_new_function)
        self.recovery = RecoveryManager(
            self.sms, self.cos, self.logs,
            num_recovery_functions=cfg.num_recovery_functions,
            retain_seconds=cfg.recovery_retain_seconds,
            clock=self.clock,
            writeback=self.writeback,
            thread_prefix=f"recovery{tag}",
            retry=self.cos_retry)
        self._pending_records: Dict[int, List[PutRecord]] = {}
        # the client-daemon thread: every mutating request runs here, in
        # submission order — async callers pipeline, sync callers block
        self._daemon_ident: Optional[int] = None
        self._exec = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"store-client{tag}",
            initializer=self._register_daemon)
        # GET-side I/O executor: COS demand reads + prefetch warms fan
        # out here while the daemon thread decodes (the workers only
        # touch thread-safe layers: writeback.peek / cos.get / clock)
        self._io = ThreadPoolExecutor(
            max_workers=max(1, cfg.get_io_workers),
            thread_name_prefix=f"store-io{tag}")
        self.prefetcher = SequentialPrefetcher(PrefetchConfig(
            enabled=cfg.prefetch and cfg.pipelined_get,
            min_run=cfg.prefetch_min_run, depth=cfg.prefetch_depth))
        # warm fetches in flight: chunk key -> Future (daemon thread only)
        self._prefetch_inflight: Dict[str, Future] = {}
        # degraded-read compaction candidates deferred off the GET
        # critical path; drained by gc_tick on the daemon thread. An
        # insertion-ordered de-dup set: bounded by the number of distinct
        # degraded chunks, not the read rate
        self._pending_migrations: Dict[str, None] = {}
        # chunk journal records pre-appended by _put_fragments that have
        # not yet been handed to the writeback queue (ckey -> seq); any
        # left behind by a failed/aborted PUT are marked dead. Daemon-
        # thread only.
        self._spill_put_seqs: Dict[str, int] = {}
        # fragment payload records: the journal holds each fragment's
        # pre-EC payload ONCE (chunk records are tiny stubs replay
        # re-encodes); the record lives until the persistent-buffer
        # entry fully drains. In-flight (this PUT) vs committed:
        self._spill_put_frag_seqs: Dict[str, int] = {}
        self._spill_frag_seqs: Dict[str, int] = {}
        # 2PC in-doubt state (daemon thread only). Live prepared batches
        # registered under a leader ticket (durable `prepared/<t>`
        # journal record appended + synced at prepare):
        self._prepared_tickets: Dict[int, _PreparedBatch] = {}
        # prepared-uncommitted batches found in the journal at restart:
        # ticket -> {"objs": [...], "seq": rec seq, "frags": {...},
        # "stubs": {...}} — their fragment/stub frames are WITHHELD from
        # ordinary replay until the leader's decision resolves them
        # (resolve_indoubt), so an aborted batch can never leak a head
        self._indoubt: Dict[int, dict] = {}
        # daemon-restart resume: replay journal records that survived a
        # crash — metadata records restore the table, pending writes
        # re-enter the queue (and thus the pending map, so GETs and
        # RecoveryManager._download serve them like live pending data)
        if self.spill is not None:
            self._replay_spill()
        if self._obs is not None:
            self._obs.event("store.open", store=name or "store",
                            pid=os.getpid())

    # ------------------------------------------------------------------
    # async plumbing
    # ------------------------------------------------------------------

    def _register_daemon(self) -> None:
        self._daemon_ident = threading.get_ident()

    def _submit(self, fn) -> StoreFuture:
        obs = self._obs
        if obs is not None:
            # executor hop: the daemon runs `fn` on its own thread —
            # close it over the submitter's ambient trace context so
            # daemon-side spans stitch under the caller's span
            fn = obs.bind_current(fn)
        fut = StoreFuture()
        if threading.get_ident() == self._daemon_ident:
            # re-entrant call from the daemon thread itself: run inline
            # (queueing would deadlock the single worker)
            try:
                fut._resolve(fn())
            except BaseException as e:            # noqa: BLE001
                fut.set_exception(e)
            return fut

        def run():
            try:
                fut._resolve(fn())
            except BaseException as e:            # noqa: BLE001
                fut.set_exception(e)
        try:
            self._exec.submit(run)
        except RuntimeError as e:
            # dead daemon (closed store): the same error class every
            # other frontend raises for an unreachable shard, so
            # callers need one except-clause across thread/process/tcp
            from .transport import ShardWorkerDied
            raise ShardWorkerDied(
                f"store {self.name!r} daemon is shut down",
                op="submit") from e
        return fut

    def flush_writeback(self, timeout: Optional[float] = None) -> bool:
        """Barrier: block until every acked PUT is persisted in COS.
        False on timeout or if any write failed out permanently (those
        payloads remain pinned in the persistent buffer). Permanent
        failures are data-at-risk: the False return path names the
        affected keys (log + `snapshot_metadata()["health"]`) instead
        of burying them in a counter."""
        ok = self.writeback.flush(timeout=timeout)
        self.stats.writeback_permanent_failures = \
            self.writeback.stats.failures
        if not ok:
            h = self.writeback.health()
            if h["failed_keys"]:
                _LOG.warning(
                    "flush_writeback%s: %d permanently-failed writes; "
                    "data-at-risk keys (first %d): %s",
                    f" [{self.name}]" if self.name else "",
                    h["permanent_failures"],
                    min(8, len(h["failed_keys"])), h["failed_keys"][:8])
            else:
                _LOG.warning(
                    "flush_writeback%s: timed out with state=%s "
                    "depth=%d consecutive_errors=%d",
                    f" [{self.name}]" if self.name else "",
                    h["state"], h["depth"], h["consecutive_errors"])
        return ok

    def pause_writeback(self) -> None:
        """Hold COS writes in-queue (tests/benchmarks). Part of the
        shard surface: front-ends (in-process or over IPC) call this
        instead of reaching into `self.writeback`."""
        self.writeback.pause()

    def resume_writeback(self) -> None:
        self.writeback.resume()

    def balance_count(self) -> int:
        """Distinct object keys (metadata heads) this store serves —
        one bar of the router-quality histogram."""
        snap = self.mt.snapshot()
        return sum(1 for k in snap if "|" not in k)

    def ledger_dollars(self) -> Dict[str, float]:
        return self.ledger.dollars()

    def close(self, *, flush: bool = True) -> bool:
        """Release the store's threads: drain the client-daemon executor
        FIRST (in-flight PUTs may still enqueue writebacks), then flush +
        stop the writeback writer, the recovery pool, and COS. Returns
        False if writes were left unpersisted. The store must not be
        used afterwards."""
        self._exec.shutdown(wait=True)
        self._io.shutdown(wait=True)
        ok = self.writeback.close(flush=flush)
        self.recovery.shutdown()
        if self._owns_cos:          # a shared (front-end-owned) COS
            self.cos.shutdown()     # outlives any one shard
        if self.spill is not None:
            self.spill.close()
            if self._spill_auto:
                # private tempdir journal: a restart can't find it, so a
                # graceful close reclaims it outright
                shutil.rmtree(self.spill_dir, ignore_errors=True)
        return ok

    def simulate_crash(self) -> Optional[str]:
        """Drop the client daemon mid-flight WITHOUT flushing — the kill
        half of the kill/restart durability tests. The queue, pending
        map, persistent buffer, and metadata table are abandoned exactly
        as a process crash would abandon them; the spill journal's
        segments (and a disk-backed COS root) survive. Returns the
        spill_dir so the caller can rebuild a store on it."""
        self._exec.shutdown(wait=True, cancel_futures=True)
        self._io.shutdown(wait=False, cancel_futures=True)
        self.writeback.close(flush=False)
        self.recovery.shutdown()
        if self._owns_cos:          # COS survives a one-shard crash
            self.cos.shutdown()
        if self.spill is not None:
            # hard close: the journal's unsynced buffer tail is
            # discarded, as a real SIGKILL would — only frames an
            # ack-point sync() covered survive
            self.spill.close(reclaim=False, hard=True)
        return self.spill_dir

    # ------------------------------------------------------------------
    # spill journal: metadata records + restart replay (§5.3.2)
    # ------------------------------------------------------------------

    def _spill_journal_meta(self, key: str, c, *,
                            ticket: Optional[int] = None) -> None:
        """Journal the committed metadata of one PUT ('meta/<key>|<ver>')
        — appended at commit, after the version's fragment/stub frames
        (replay does not depend on file order: metadata is restored
        during the scan, chunks re-enqueue afterwards). The record lives
        until the version is superseded (or folded into a `metasnap`
        snapshot) — it is what makes an acked object *resolvable* after
        a restart. A cross-shard commit stamps its leader ticket into
        the record (diagnostic ordering evidence across shard journals)."""
        obj = f"{key}|{c.ver}"
        rec = {"key": key, "ver": c.ver, "prev_ver": c.prev_ver,
               "num_fragments": c.num_fragments, "size": c.size}
        if ticket is not None:
            rec["ticket"] = ticket
        seq = self.spill.append(f"meta/{obj}", json.dumps(rec).encode())
        with self._lock:
            self._spill_meta_seqs[obj] = seq

    def _spill_drop_meta(self, obj: str) -> None:
        """Logically truncate a metadata record (version superseded, PUT
        failed, or PUT aborted mid-flight). A record whose durable copy
        lives inside the current `metasnap` snapshot cannot be
        individually truncated — a `metadrop/` tombstone is journaled
        instead (replayed in seq order, so it kills the snapshot's copy
        but never a later re-PUT); the NEXT snapshot truncates the
        tombstones it obsoletes."""
        if self.spill is None:
            return
        with self._lock:
            seq = self._spill_meta_seqs.pop(obj, None)
        if seq is None:
            return
        if seq == _SNAP_COVERED:
            ts = self.spill.append(f"metadrop/{obj}", b"")
            with self._lock:
                self._spill_tombstones.append(ts)
        else:
            self.spill.mark_persisted(seq)

    def _maybe_snapshot_meta(self) -> None:
        """Size-bounded metadata log (gc_tick): once enough individual
        `meta/` records + `metadrop/` tombstones accumulate, fold the
        whole journaled metadata table into ONE `metasnap` record at a
        fresh journal generation (forced segment rotation) and truncate
        everything it supersedes. Caps a long-lived daemon's replay at
        one snapshot plus the post-snapshot tail.

        Crash-window ordering: the snapshot is appended FIRST; the
        truncation (PERSIST) frames follow it into the same group
        commit. A torn tail can therefore only lose truncations — replay
        then sees both the snapshot and some superseded records, and the
        seq-ordered merge (newest head wins, tombstones kill only older
        registrations) converges to the same table. The `metasnap` key
        is constant, so the journal's same-key supersession retires the
        previous snapshot automatically even if its PERSIST frame tears."""
        lim = self.cfg.spill_meta_snapshot_records
        if self.spill is None or not lim:
            return
        with self._lock:
            individual = sum(1 for s in self._spill_meta_seqs.values()
                             if s != _SNAP_COVERED)
            work = individual + len(self._spill_tombstones)
        if work < lim:
            return
        with self._lock:
            objs = list(self._spill_meta_seqs)
        entries = []
        for obj in objs:
            m = self.mt.load(obj)
            if m is None or not m.is_done_ok():
                continue
            entries.append({"key": m.key, "ver": m.ver,
                            "prev_ver": m.prev_ver,
                            "num_fragments": m.num_fragments,
                            "size": m.size})
        self.spill.rotate()               # new journal generation
        # constant key: the journal's same-key supersession retires the
        # previous snapshot the moment this one is appended
        self.spill.append("metasnap", json.dumps(entries).encode())
        with self._lock:
            old_seqs = [s for s in self._spill_meta_seqs.values()
                        if s != _SNAP_COVERED]
            for obj in self._spill_meta_seqs:
                self._spill_meta_seqs[obj] = _SNAP_COVERED
            tombs, self._spill_tombstones = self._spill_tombstones, []
        for s in old_seqs + tombs:
            self.spill.mark_persisted(s)
        self.spill.sync()
        self.stats.inc("spill_meta_snapshots")

    def _replay_spill(self) -> None:
        """Re-enqueue every journal record that survived the previous
        daemon: metadata records rebuild the table (newest version wins
        the head); fragment records restore their persistent-buffer
        entries (one ref per surviving chunk stub) and are re-encoded —
        deterministic RS — to regenerate each stub's chunk payload for
        the queue; log/snapshot records re-enter the queue as-is. The
        pending map + buffer then serve post-restart GETs and recovery
        exactly like live pending data, and the background writer
        persists everything to COS."""
        frag_payloads: Dict[str, object] = {}
        frag_seqs: Dict[str, int] = {}
        stubs: Dict[str, List[Tuple[int, str]]] = {}  # fkey -> (seq, key)
        for seq, key, data in self.spill.take_pending():
            if key.startswith("meta/"):
                self._spill_restore_meta(seq, data)
            elif key == "metasnap":
                # a metadata-table snapshot (one per journal generation):
                # registers every contained meta as snapshot-covered
                self._spill_restore_snapshot(seq, data)
            elif key.startswith("metadrop/"):
                # tombstone for a snapshot-covered meta superseded after
                # the snapshot was taken — seq order guarantees it kills
                # only registrations made before it
                self._spill_replay_tombstone(seq, key[len("metadrop/"):])
            elif key.startswith("frag/"):
                fkey = key[len("frag/"):]
                frag_payloads[fkey] = data
                frag_seqs[fkey] = seq
            elif key.startswith("chunk/"):        # stub: payload derived
                ckey = key[len("chunk/"):]
                stubs.setdefault(ckey.rsplit("#", 1)[0],
                                 []).append((seq, key))
            elif key.startswith("prepared/"):
                # a 2PC sub-batch prepared but not resolved pre-crash:
                # in doubt until the leader's decision is consulted
                self._spill_restore_prepared(seq, key[len("prepared/"):],
                                             data)
            else:
                self.writeback.enqueue(key, data, seq=seq)
                self.stats.inc("spill_replayed_writes")
        # Withhold every in-doubt batch's fragment/stub frames from
        # ordinary replay: they must neither re-enter the writeback
        # queue nor restore buffer entries until the leader's decision
        # says commit (resolve_indoubt releases or truncates them).
        if self._indoubt:
            indoubt_objs: Dict[str, int] = {}
            for t, e in self._indoubt.items():
                for d in e["objs"]:
                    indoubt_objs[f"{d['key']}|{d['ver']}"] = t
            for fkey in list(frag_seqs):
                t = indoubt_objs.get(fkey.rpartition("/f")[0])
                if t is None:
                    continue
                e = self._indoubt[t]
                e["frags"][fkey] = (frag_seqs.pop(fkey),
                                    frag_payloads.pop(fkey))
                e["stubs"][fkey] = stubs.pop(fkey, [])
        # A superseded meta can be resurrected alongside its successor
        # when the PERSIST frame truncating it was lost (torn tail): the
        # live put path only ever truncates the current head's
        # predecessor, so a non-head record restored here would pin its
        # segment (and be replayed, and re-compacted) forever. Re-drop
        # everything below each key's restored head now.
        with self._lock:
            restored = list(self._spill_meta_seqs)
        heads: Dict[str, int] = {}
        for obj in restored:
            key, ver = obj.rsplit("|", 1)
            heads[key] = max(heads.get(key, 0), int(ver))
        for obj in restored:
            key, ver = obj.rsplit("|", 1)
            if int(ver) < heads[key]:
                self._spill_drop_meta(obj)
        live = []                                 # (fkey, u8, stub items)
        for fkey, seq in frag_seqs.items():
            items = stubs.pop(fkey, [])
            if not items:
                # every chunk persisted pre-crash (their truncation
                # frames made it, the fragment's did not): record is dead
                self.spill.mark_persisted(seq)
                continue
            u8 = as_u8(frag_payloads[fkey])
            # restore the buffer entry: one ref per outstanding chunk,
            # released as each persists — the live draining contract
            self.pb.create(fkey, u8, refs=len(items))
            with self._lock:
                self._spill_frag_seqs[fkey] = seq
            live.append((fkey, u8, items))
        for (fkey, u8, items), chunks in zip(
                live, self.codec.encode_many([u for _, u, _ in live],
                                             as_arrays=True)
                if live else []):
            for seq, cos_key in items:
                idx = int(cos_key.rsplit("#", 1)[1])
                self.writeback.enqueue(cos_key, chunks[idx].copy(),
                                       seq=seq,
                                       on_done=self._on_chunk_persisted)
                self.stats.inc("spill_replayed_writes")
        for items in stubs.values():              # stubs whose fragment
            for seq, _ in items:                  # is gone (corruption):
                self.spill.mark_persisted(seq)    # unrecoverable, drop

    def _spill_restore_prepared(self, seq: int, tstr: str, data) -> None:
        """Restore one `prepared/<ticket>` record into the in-doubt map.
        Malformed records are truncated — without a parsable object list
        there is nothing to withhold or resolve."""
        try:
            ticket = int(tstr)
            objs = json.loads(bytes(data))
            if not isinstance(objs, list):
                raise ValueError("prepared record is not a list")
            for d in objs:
                d["key"], int(d["ver"])           # shape check
        except (ValueError, KeyError, TypeError):
            self.spill.mark_persisted(seq)
            return
        self._indoubt[ticket] = {"objs": objs, "seq": seq,
                                 "frags": {}, "stubs": {}}

    # ------------------------------------------------------------------
    # 2PC in-doubt resolution (restart-time sweep; see repro.core.shard)
    # ------------------------------------------------------------------

    def indoubt_tickets(self) -> List[int]:
        """Tickets of prepared-uncommitted batches this store knows
        about: live registrations plus journal-replayed ones. The
        cross-shard resolver sweeps these after any shard restart."""
        return self.indoubt_tickets_async().result()

    def indoubt_tickets_async(self) -> StoreFuture:
        """Non-blocking `indoubt_tickets` — single-threaded callers
        (the process-host worker loop) must not park behind the daemon
        queue while earlier ops depend on them for progress."""
        return self._submit(lambda: sorted(
            set(self._indoubt) | set(self._prepared_tickets)))

    def resolve_indoubt(self, ticket: int, *, commit: bool) -> StoreFuture:
        """Resolve one in-doubt prepared batch per the leader's durable
        decision: roll it forward (commit — every version becomes a
        readable head, exactly as if round 2 had run) or back (abort —
        its frames are truncated, no version ever becomes visible).
        Resolves to {key: version} on commit, None for an unknown
        ticket or an abort. Idempotent: a ticket already resolved (or
        never prepared here) is a no-op."""
        return self._submit(lambda: self._resolve_indoubt_impl(
            ticket, commit))

    def _resolve_indoubt_impl(self, ticket: int, commit: bool):
        prep = self._prepared_tickets.get(ticket)
        if prep is not None:                      # live prepared batch
            self.stats.inc("indoubt_resolved")
            if commit:
                # a failure propagates with the batch still registered:
                # the decision is durable, so the resolver retries the
                # (idempotent) commit rather than half-aborting
                return self._put_many_commit(prep, ticket=ticket)
            self._put_many_abort(prep)
            return None
        e = self._indoubt.pop(ticket, None)
        if e is None:
            return None
        self.stats.inc("indoubt_resolved")
        return self._resolve_indoubt_replayed(e, ticket, commit)

    def _resolve_indoubt_replayed(self, e: dict, ticket: int,
                                  commit: bool):
        """Resolve a journal-replayed in-doubt batch (the shard crashed
        between prepare and the leader's round 2 reaching it).

        Abort: truncate the batch's withheld frames + prepared record —
        presumed-abort finishes the roll-back the crash started.

        Commit: install + journal each object's metadata (skipping any
        already restored — the crash may have landed mid-commit, after
        some `meta/` frames synced) and re-enqueue the withheld chunk
        writes exactly like ordinary replay. An object with no withheld
        fragment frames already drained to COS pre-crash (its frames
        were truncated on full persistence), so metadata alone
        finishes it."""
        if not commit:
            for fkey, (fseq, _) in e["frags"].items():
                self.spill.mark_persisted(fseq)
            for items in e["stubs"].values():
                for seq, _ in items:
                    self.spill.mark_persisted(seq)
            self.spill.mark_persisted(e["seq"])
            self.spill.sync()
            return None
        out: Dict[str, int] = {}
        for d in e["objs"]:
            key, ver = d["key"], int(d["ver"])
            obj = f"{key}|{ver}"
            with self._lock:
                have_meta = obj in self._spill_meta_seqs
            if not have_meta:
                m = Meta(key, ver, int(d.get("prev_ver", 0)))
                m.num_fragments = int(d.get("num_fragments", 1))
                m.size = int(d.get("size", 0))
                m.done(True)
                self.mt.store(obj, m)
                head = self.mt.load(key)
                if head is None or head.ver <= ver:
                    self.mt.store(key, m)
                self._spill_journal_meta(key, m, ticket=ticket)
            out[key] = ver
        live = []                                 # (fkey, u8, stub items)
        for fkey, (fseq, payload) in e["frags"].items():
            items = e["stubs"].get(fkey) or []
            if not items:
                self.spill.mark_persisted(fseq)   # chunks fully drained
                continue
            u8 = as_u8(payload)
            self.pb.create(fkey, u8, refs=len(items))
            with self._lock:
                self._spill_frag_seqs[fkey] = fseq
            live.append((fkey, u8, items))
        for (fkey, u8, items), chunks in zip(
                live, self.codec.encode_many([u for _, u, _ in live],
                                             as_arrays=True)
                if live else []):
            for seq, cos_key in items:
                idx = int(cos_key.rsplit("#", 1)[1])
                self.writeback.enqueue(cos_key, chunks[idx].copy(),
                                       seq=seq,
                                       on_done=self._on_chunk_persisted)
                self.stats.inc("spill_replayed_writes")
        self.spill.mark_persisted(e["seq"])
        self.spill.sync()
        self.stats.inc("commit_tickets")
        return out

    def _spill_register_meta(self, d: dict, seq: int) -> None:
        """Install one replayed metadata entry (individual record or a
        snapshot element): table entry, head if newest, seq
        registration (`_SNAP_COVERED` when the durable copy is the
        snapshot). Raises on malformed input — callers decide how to
        truncate."""
        key, ver = d["key"], int(d["ver"])
        m = Meta(key, ver, int(d.get("prev_ver", 0)))
        m.num_fragments = int(d.get("num_fragments", 1))
        m.size = int(d.get("size", 0))
        m.done(True)
        self.mt.store(f"{key}|{ver}", m)
        head = self.mt.load(key)
        if head is None or head.ver <= ver:
            self.mt.store(key, m)
        obj = f"{key}|{ver}"
        with self._lock:
            old = self._spill_meta_seqs.get(obj)
            self._spill_meta_seqs[obj] = seq
        if old is not None and old != _SNAP_COVERED and old != seq:
            # the same obj was already registered from an individual
            # record whose truncation frame tore away (crash between a
            # snapshot's append and its PERSIST frames): the new
            # registration supersedes it — truncate the stale record or
            # it pins its segment (and is re-replayed) forever
            self.spill.mark_persisted(old)
        self.stats.inc("spill_replayed_metas")

    def _spill_restore_meta(self, seq: int, data) -> None:
        try:
            self._spill_register_meta(json.loads(bytes(data)), seq)
        except (ValueError, KeyError, TypeError):
            # malformed record: unrestorable — truncate it so it cannot
            # pin its segment (and replay cost) forever
            self.spill.mark_persisted(seq)

    def _spill_restore_snapshot(self, seq: int, data) -> None:
        """Restore a `metasnap` record: every contained meta registers
        as snapshot-covered (supersession must tombstone, not truncate).
        Malformed elements are skipped — each element is independent."""
        try:
            entries = json.loads(bytes(data))
        except ValueError:
            self.spill.mark_persisted(seq)        # unrestorable snapshot
            return
        if not isinstance(entries, list):
            self.spill.mark_persisted(seq)
            return
        for d in entries:
            try:
                self._spill_register_meta(d, _SNAP_COVERED)
            except (ValueError, KeyError, TypeError):
                continue

    def _spill_replay_tombstone(self, seq: int, obj: str) -> None:
        """Apply a `metadrop/` tombstone during replay: kill the earlier
        registration of `obj` (individual records additionally truncate
        — a snapshot copy cannot). The tombstone itself stays live until
        the next snapshot folds it away."""
        with self._lock:
            reg = self._spill_meta_seqs.pop(obj, None)
            self._spill_tombstones.append(seq)
        if reg is not None and reg != _SNAP_COVERED:
            self.spill.mark_persisted(reg)

    def cos_keys(self, prefix: str = "") -> List[str]:
        """COS key listing that includes acked-but-not-yet-persisted
        writes (the pending writeback map)."""
        keys = set(self.cos.list_keys(prefix))
        keys.update(self.writeback.pending_keys(prefix))
        return sorted(keys)

    # ------------------------------------------------------------------
    # function lifecycle
    # ------------------------------------------------------------------

    def _on_new_function(self, fid: int, fg_id: int, capacity: int) -> None:
        self.sms.add(fid, capacity)
        # with async writeback, log-node persistence rides the background
        # writer (the instance persists on return, §5.5.1 — not the
        # client's ack path); reads stay correct via the pending map
        self.logs[fid] = InsertionLog(
            fid, self.cos,
            writeback=self.writeback if self.cfg.async_writeback else None)
        self.daemon_view[fid] = Piggyback()
        self.window.latest.add_function(fid, fg_id)
        self.recovery.assign_group(fid, list(self.sms.slabs.keys()))

    def _invoke(self, fid: int, nbytes: int, category: str) -> None:
        """Invoke a function instance: failure detection happens here, on
        invocation, exactly as in the paper (§5.5.1)."""
        slab = self.sms.get(fid)
        busy = self.cfg.busy_base_s + nbytes * self.cfg.busy_per_byte_s
        was_dead = not slab.alive
        slab.invoke(busy)
        gb = slab.capacity / (1024 ** 3)
        self.ledger.invoke(category, gb=gb, seconds=busy)
        view = self.daemon_view.get(fid, Piggyback())
        detected = self.recovery.check_failed(slab, view)
        if was_dead and not detected:
            # observed-dead at invocation is a real detection even when
            # term/hash happen to match (e.g. a never-written instance) —
            # without this, stats.detections undercounts
            self.recovery.note_detection()
        failed = detected or was_dead
        if failed and view.term > 0 and self.cfg.enable_recovery:
            self._recover(fid)

    def _recover(self, fid: int) -> None:
        slab = self.sms.get(fid)
        view = self.daemon_view[fid]
        candidates = [f for f in self.sms.slabs
                      if self.window.state_of_function(f)
                      == BucketState.ACTIVE]
        t0 = self.clock.now()
        if self.recovery.needs_parallel(slab, view):
            session = self.recovery.recover_parallel(slab, candidates)
            nbytes = sum(len(v) for v in session.recovered.values())
            for rfid in session.group:
                self.ledger.invoke("recovery",
                                   gb=self.sms.get(rfid).capacity / 1024**3,
                                   seconds=self.cfg.busy_base_s
                                   + nbytes / max(len(session.group), 1)
                                   * self.cfg.busy_per_byte_s)
        else:
            n = self.recovery.recover_local(slab)
            self.ledger.invoke("recovery", gb=slab.capacity / 1024**3,
                               seconds=self.cfg.busy_base_s
                               + n * self.cfg.busy_per_byte_s * 1024)
        del t0

    # ------------------------------------------------------------------
    # PUT (Appendix A left + §5.3.1/§5.3.2)
    # ------------------------------------------------------------------

    def put(self, key: str, value) -> int:
        """Strongly-consistent versioned PUT (blocking wrapper over
        `put_async`). Returns the version."""
        return self.put_async(key, value).result()

    @staticmethod
    def _snapshot_value(value):
        """Snapshot mutable host buffers ON THE CALLER'S THREAD, at
        submission: once put_async returns, the caller may reuse its
        buffer — the store must already own a stable copy. bytes and
        device arrays are immutable and pass through zero-copy."""
        if needs_snapshot(value):
            snap = as_u8(value).copy()
            # the snapshot is store-owned and immutable by contract;
            # marking it read-only makes a second snapshot pass (the
            # sharded front-end snapshots at its surface, then delegates
            # into a shard's put_many_async) a no-op instead of another
            # full memcpy of the payload
            snap.flags.writeable = False
            return snap
        return value

    def put_async(self, key: str, value) -> StoreFuture:
        """Non-blocking PUT. The future resolves to the committed version
        once fragments land in SMS slabs + the persistent buffer; COS
        persistence continues in the background (see module docstring).
        The payload is captured at submission — the caller may mutate or
        reuse its buffer immediately."""
        value = self._snapshot_value(value)
        return self._submit(
            lambda: self._put_many_impl([(key, value)],
                                        raise_on_conflict=True)[key])

    def put_many(self, items, *, raise_on_conflict: bool = False
                 ) -> Dict[str, int]:
        """Batch PUT (blocking wrapper over `put_many_async`)."""
        return self.put_many_async(
            items, raise_on_conflict=raise_on_conflict).result()

    def put_many_async(self, items, *, raise_on_conflict: bool = False
                       ) -> StoreFuture:
        """Batch PUT: ONE leader-sequenced multi-key CAS round commits
        the whole batch's metadata, ALL fragments of ALL objects go
        through a single `encode_many` codec call, and chunk writes are
        grouped per function (one invoke + one insertion-log append
        each). items: dict or iterable of (key, value). The future
        resolves to {key: version} (-1 on failure), matching `put` per
        key. A CAS conflict on one key fails only that key (-1) unless
        raise_on_conflict (the single-key `put` contract: raise so the
        caller retries)."""
        items = list(items.items()) if isinstance(items, dict) \
            else list(items)
        items = [(k, self._snapshot_value(v)) for k, v in items]
        obs = self._obs
        with (obs.span("client.put_many", n=len(items))
              if obs is not None else NOOP_CM):
            return self._submit(
                lambda: self._put_many_impl(
                    items, raise_on_conflict=raise_on_conflict))

    def _put_many_impl(self, items, *, raise_on_conflict: bool = False
                       ) -> Dict[str, int]:
        """Single-store PUT batch: prepare + immediate self-commit (the
        degenerate one-shard case of the cross-shard protocol)."""
        obs = self._obs
        t0 = time.perf_counter() if obs is not None else 0.0
        with (obs.span("daemon.put_many", n=len(items))
              if obs is not None else NOOP_CM):
            prep = self._put_many_prepare(
                items, raise_on_conflict=raise_on_conflict)
            try:
                out = self._put_many_commit(prep)
            except BaseException:
                # a commit-side failure (GC / journal I/O) must not leave
                # PENDING heads behind — readers would block and later
                # PUTs would conflict forever
                self._put_many_abort(prep)
                raise
        if obs is not None:
            obs.record("put.ack_us", (time.perf_counter() - t0) * 1e6)
        return out

    def prepare_put_many_async(self, items, *,
                               raise_on_conflict: bool = False,
                               ticket: Optional[int] = None
                               ) -> StoreFuture:
        """Round 1 of the cross-shard commit protocol: run this shard's
        sub-batch up to (but NOT including) the ack point. The future
        resolves to an opaque prepared-batch handle for
        `commit_put_many_async` / `abort_put_many_async`. Until one of
        those runs, the new versions are PENDING — invisible to readers
        and un-acked. Same-key PUTs meanwhile wait on the pending head
        exactly like any concurrent PUT.

        `ticket` (leader-issued, cross-shard batches only) makes the
        prepare DURABLE: a `prepared/<ticket>` record naming every
        (key, version) of the sub-batch is journaled and synced before
        the future resolves, so a crashed shard restarts knowing exactly
        which batches were in doubt — `indoubt_tickets()` surfaces them
        and `resolve_indoubt()` rolls each forward or back once the
        leader's decision is known."""
        items = list(items.items()) if isinstance(items, dict) \
            else list(items)
        items = [(k, self._snapshot_value(v)) for k, v in items]

        obs = self._obs

        def run():
            with (obs.span("daemon.2pc_prepare", ticket=ticket)
                  if obs is not None else NOOP_CM):
                prep = self._put_many_prepare(
                    items, raise_on_conflict=raise_on_conflict)
                if ticket is not None:
                    try:
                        self._register_prepared(prep, ticket)
                    except BaseException:
                        self._put_many_abort(prep)
                        raise
                return prep
        return self._submit(run)

    def _register_prepared(self, prep: "_PreparedBatch",
                           ticket: int) -> None:
        """Journal + sync this batch's durable `prepared/<ticket>`
        record (PREPARE DURABILITY POINT: the record and the batch's
        payload frames — appended earlier, flushed by this same sync —
        must survive a crash for the leader's decision to be
        actionable) and register the live batch for the resolver."""
        prep.ticket = ticket
        if self.spill is not None:
            objs = [{"key": k, "ver": ver, "prev_ver": c.prev_ver,
                     "num_fragments": c.num_fragments, "size": c.size}
                    for k, c, ver, _ in prep.metas]
            prep.prepared_seq = self.spill.append(
                f"prepared/{ticket}", json.dumps(objs).encode())
            self.spill.sync()
        self._prepared_tickets[ticket] = prep

    def _drop_prepared(self, prep: "_PreparedBatch") -> None:
        """Retire a resolved batch's prepared record + registration
        (the caller's journal sync makes the truncation durable)."""
        if prep.ticket is not None:
            self._prepared_tickets.pop(prep.ticket, None)
        if prep.prepared_seq is not None and self.spill is not None:
            self.spill.mark_persisted(prep.prepared_seq)
            prep.prepared_seq = None

    def commit_put_many_async(self, prep: "_PreparedBatch", *,
                              ticket: Optional[int] = None) -> StoreFuture:
        """Round 2 (commit): finalize a prepared sub-batch under the
        leader's commit ticket. Resolves to {key: version} like
        `put_many`. A commit-side failure (journal I/O, GC) on an
        UN-ticketed batch aborts the unfinalized heads before
        propagating — a PENDING head left behind would block every
        later reader and writer of that key forever. A TICKETED batch
        must NOT abort here: the leader's commit decision is already
        durable, so aborting one shard would leave the batch
        half-visible forever — the batch stays registered in doubt and
        the cross-shard resolver retries the (idempotent) commit."""
        obs = self._obs

        def run():
            with (obs.span("daemon.2pc_commit", ticket=ticket)
                  if obs is not None else NOOP_CM):
                try:
                    return self._put_many_commit(prep, ticket=ticket)
                except BaseException:
                    if ticket is None:
                        self._put_many_abort(prep)
                    raise
        return self._submit(run)

    def abort_put_many_async(self, prep: "_PreparedBatch") -> StoreFuture:
        """Round 2 (abort): roll a prepared sub-batch back so none of
        its versions ever becomes visible (another shard failed to
        prepare — the batch must not be half-visible)."""
        return self._submit(lambda: self._put_many_abort(prep))

    def _put_many_prepare(self, items, *, raise_on_conflict: bool = False
                          ) -> "_PreparedBatch":
        """CAS-install the version heads (they stay PENDING), fragment,
        store chunks into SMS slabs, journal payload + stub frames, and
        hand chunk persistence to the writeback queue. Everything up to
        — but excluding — the ack point: metadata completion, the meta
        journal record, old-version GC, and the journal group-commit
        all wait for `_put_many_commit`."""
        if len({k for k, _ in items}) != len(items):
            # a duplicate key would CAS against its own in-flight version
            raise ValueError("duplicate keys in put_many batch")
        prep = _PreparedBatch(raise_on_conflict=raise_on_conflict)
        conflicted = prep.conflicted
        installed = prep.installed
        metas = prep.metas
        frags: List[Tuple[str, np.ndarray]] = []
        try:
            cands = []
            for key, value in items:
                self.stats.inc("puts")
                if is_array_payload(value):
                    self.stats.inc("array_payload_puts")
                self._track_queue(payload_nbytes(value))
                cands.append((key, value, self.mt.prepare(key, 1)))
            # multi-key CAS: one metadata round per retry wave, not one
            # round per key
            pending = cands
            while pending:
                self.stats.inc("cas_rounds")
                results = self.mt.cas_many([(k, c) for k, _, c in pending])
                nxt = []
                for (key, value, c), (m, ok) in zip(pending, results):
                    if ok:
                        # prepared-but-uncommitted until _put_many_commit
                        # (see Meta.prepared; cleared by done())
                        c.prepared = True
                        installed.append((key, value, c))
                    elif not m.is_done():         # concurrent PUT in flight
                        # a prepared 2PC head resolves via a commit task
                        # queued BEHIND us on this same daemon — waiting
                        # would stall the whole shard until the timeout,
                        # so conflict immediately on those
                        if not m.prepared:
                            m.wait(timeout=5.0)
                        if raise_on_conflict:
                            raise ConcurrentPutError(key)
                        conflicted.append(key)
                    else:
                        c.revise(m.ver + 1)
                        nxt.append((key, value, c))
                pending = nxt
            for key, value, c in installed:
                ver = c.ver
                self.mt.store(f"{key}|{ver}", c)
                # register for cleanup BEFORE fragmenting: once the CAS
                # installed c as the head, any failure below must still
                # finalize this key (fkeys is mutated in place)
                fkeys: List[str] = []
                metas.append((key, c, ver, fkeys))
                # mutable buffers were snapshotted at submission
                # (_snapshot_value), so this view is store-owned or
                # immutable-backed either way
                u8 = as_u8(value)
                fb = self.cfg.fragment_bytes
                fragments = [u8[i:i + fb]
                             for i in range(0, max(u8.size, 1), fb)]
                c.num_fragments = len(fragments)
                c.size = u8.size
                for fi, frag in enumerate(fragments):
                    fkey = f"{key}|{ver}/f{fi}"
                    # persistent buffer: one ref held by the PUT itself;
                    # each async chunk writeback retains another and
                    # releases it on persistence (§5.3.2 draining)
                    self.pb.create(fkey, frag)
                    fkeys.append(fkey)
                    frags.append((fkey, frag))
            prep.failed = self._put_fragments(frags)
        except BaseException:
            # finalize every CAS-installed key that hasn't completed as
            # failed so no metadata head stays PENDING forever (readers
            # would block and later puts would raise on every attempt) —
            # covers CAS conflicts, encode/placement errors, MemoryError
            self._spill_abort_chunks()    # never handed to the queue
            for mkey, c, mver, fkeys in metas:
                if not c.is_done():
                    for fkey in fkeys:
                        self.pb.release_all(fkey)
                        self._spill_drop_frag(fkey)
                    c.done(False)
                    self._spill_drop_meta(f"{mkey}|{mver}")
            for _, _, c in installed:
                if not c.is_done():               # installed, not fragmented
                    c.done(False)
            raise
        return prep

    def _put_many_commit(self, prep: "_PreparedBatch", *,
                         ticket: Optional[int] = None) -> Dict[str, int]:
        """The ACK POINT: chunks are in SMS slabs, fragments in the
        persistent buffer, insertion logs appended — mark each version
        done, journal its metadata, GC the superseded version, and
        group-commit the journal. COS chunk persistence keeps draining
        asynchronously from the writeback queue; the buffer entry lives
        until its last chunk persists. `ticket` is the leader-issued
        cross-shard commit sequence (recorded in the journaled
        metadata); None for single-store batches."""
        if prep.resolved:                     # double-commit is a bug
            raise RuntimeError("prepared batch already resolved")
        out: Dict[str, int] = {}
        for key, c, ver, fkeys in prep.metas:
            obj = f"{key}|{ver}"
            if obj in prep.committed:         # retried ticketed commit
                out[key] = ver if c.is_done_ok() else -1
                continue
            frag_failed = any(fk in prep.failed for fk in fkeys)
            if not frag_failed and self.spill is not None:
                # journal the metadata FIRST — the only failure-prone
                # step of this obj's finalization, so an I/O error here
                # leaves the obj untouched and the commit retryable (the
                # journal's same-key supersession absorbs a duplicate
                # append on retry). The record still lands AFTER the
                # version's payload frames (appended in
                # _put_fragments): a torn tail then can only lose the
                # meta of a PUT whose data frames are also gone —
                # replay can never restore a head version with no
                # recoverable data, which would shadow the older
                # durable version
                self._spill_journal_meta(key, c, ticket=ticket)
            for fkey in fkeys:
                if frag_failed:
                    self.pb.release_all(fkey)
                    self._spill_drop_frag(fkey)
                elif self.pb.release(fkey):   # drop the PUT's own ref
                    self._spill_drop_frag(fkey)
            ok = c.done(not frag_failed)
            if ok and c.prev_ver > 0:
                self._gc_old_version(key, c.prev_ver)
            prep.committed.add(obj)
            out[key] = ver if ok else -1
        if ticket is not None:
            self.stats.inc("commit_tickets")
        self._drop_prepared(prep)
        if self.spill is not None:
            # ACK DURABILITY POINT: group-commit every journal frame
            # this batch appended (metadata + chunk + log records,
            # plus the prepared-record truncation) before any caller
            # observes the ack
            obs = self._obs
            t0 = time.perf_counter() if obs is not None else 0.0
            self.spill.sync()
            if obs is not None:
                obs.record("put.journal_sync_us",
                           (time.perf_counter() - t0) * 1e6)
        for key in prep.conflicted:
            out[key] = -1
        prep.resolved = True
        return out

    def _put_many_abort(self, prep: "_PreparedBatch") -> None:
        """Roll a prepared batch back: no version of it may ever become
        visible. Persistent-buffer entries and journal payload records
        are dropped, slab chunks rolled back out, heads finalized as
        failed (readers fall through to the previous version). Chunks
        already handed to the writeback queue may still persist as
        orphans in COS — they are unreachable: no committed metadata
        references them. Idempotent: aborting an already-resolved batch
        (the leader's best-effort abort fan-out) is a no-op."""
        if prep.resolved:
            return
        for key, c, ver, fkeys in prep.metas:
            if c.is_done():                       # already finalized
                continue
            for fkey in fkeys:
                self.pb.release_all(fkey)
                self._spill_drop_frag(fkey)
                for idx in range(self.cfg.ec.n):
                    self._free_chunk(f"{fkey}#{idx}")
            c.done(False)
        for _, _, c in prep.installed:
            if not c.is_done():
                c.done(False)
        self._drop_prepared(prep)
        if self.spill is not None:
            self.spill.sync()                     # persist the truncations
        prep.resolved = True

    def _free_chunk(self, ckey: str) -> None:
        """Drop one chunk from the daemon's chunk map and its slab,
        releasing the placement bytes — the rollback shared by
        superseded-version GC and 2PC batch abort."""
        with self._lock:
            fid = self.chunk_map.pop(ckey, None)
        if fid is not None and fid in self.sms.slabs:
            slab = self.sms.get(fid)
            data = slab.load(ckey)
            if slab.delete(ckey) and data is not None:
                self.placement.release(fid, len(data))
        self.window.unmark(ckey)

    def _gc_old_version(self, key: str, ver: int) -> None:
        """Free the superseded version's SMS chunks (COS retains them for
        any concurrent reader still on the old version)."""
        self._spill_drop_meta(f"{key}|{ver}")   # newer version journaled
        m = self.mt.load(f"{key}|{ver}")
        nfrags = m.num_fragments if m is not None else 1
        for fi in range(nfrags):
            for idx in range(self.cfg.ec.n):
                self._free_chunk(f"{key}|{ver}/f{fi}#{idx}")

    def _place_chunk(self, idx: int, nbytes: int) -> int:
        """PlaceChunk with the SLAB as the authority on fullness: if the
        placement ledger drifted (migrations/recovery add slab bytes it
        doesn't see), seal the FG to resync and probe on."""
        while True:
            fid = self.placement.place_chunk(idx, nbytes)
            slab = self.sms.get(fid)
            if slab.used < slab.hardcap:
                return fid
            self.placement.seal_fg(self.placement.functions[fid].fg_id)

    def _persist_chunk(self, fkey: str, ckey: str, chunk) -> None:
        """Route one chunk's COS persistence: inline on the ack path
        (legacy mode) or via the background writeback queue (handing
        over the journal record _put_fragments pre-appended)."""
        self.ledger.cos_op("put")
        if self.cfg.async_writeback:
            self.pb.retain(fkey)
            self.writeback.enqueue(f"chunk/{ckey}", chunk,
                                   seq=self._spill_put_seqs.pop(ckey, None),
                                   on_done=self._on_chunk_persisted)
        else:
            self.cos.put(f"chunk/{ckey}", chunk)

    def _spill_abort_chunks(self) -> None:
        """Kill pre-appended chunk/fragment journal records that were
        never handed over (their fragment failed or the PUT aborted)."""
        seqs, self._spill_put_seqs = self._spill_put_seqs, {}
        fseqs, self._spill_put_frag_seqs = self._spill_put_frag_seqs, {}
        if self.spill is not None:
            for seq in list(seqs.values()) + list(fseqs.values()):
                self.spill.mark_persisted(seq)

    def _spill_drop_frag(self, fkey: str) -> None:
        """The fragment's persistent-buffer entry fully drained (every
        chunk persisted): truncate its journal payload record."""
        if self.spill is None:
            return
        with self._lock:
            seq = self._spill_frag_seqs.pop(fkey, None)
        if seq is not None:
            self.spill.mark_persisted(seq)

    def _on_chunk_persisted(self, cos_key: str, ok: bool) -> None:
        """Writeback completion: drop the chunk's persistent-buffer ref
        (the last drop also truncates the fragment's journal record).
        A write that exhausted its retries keeps the ref — the buffer
        stays the durable copy rather than silently losing data."""
        if ok:
            fkey = cos_key[len("chunk/"):].rsplit("#", 1)[0]
            if self.pb.release(fkey):
                self._spill_drop_frag(fkey)

    def _put_fragments(self, frags: List[Tuple[str, np.ndarray]]
                       ) -> Set[str]:
        """Encode ALL fragments in one `encode_many` call (array chunks:
        uint8 views into the stacked encode buffer, no bytes copies),
        place every chunk, then drain the writes grouped by target
        function: one `_invoke` covering the function's whole byte share
        (amortizing the per-request busy-time base of the billing model,
        §5.2) and one insertion-log append per function (§5.5.1).
        Returns the set of fragment keys whose chunks failed to store."""
        if not frags:
            return set()
        obs = self._obs
        with (obs.span("ec.encode", fragments=len(frags))
              if obs is not None else NOOP_CM):
            all_chunks = self.codec.encode_many(
                [frag for _, frag in frags], as_arrays=True)
        # single-fragment batches skip the compaction memcpy: the stacked
        # encode buffer IS that fragment's chunk set (data rows + parity,
        # ~(k+p)/k of the payload), so aliasing it pins nothing foreign —
        # and the copy was GIL-held time that throttled multi-daemon
        # scale-out. Multi-fragment batches still compact each chunk out
        # so one long-lived chunk never pins the whole batch buffer.
        compact = len(frags) > 1
        groups: Dict[int, List[Tuple[str, str, object]]] = {}
        for (fkey, _), chunks in zip(frags, all_chunks):
            for idx, chunk in enumerate(chunks):
                ckey = f"{fkey}#{idx}"
                fid = self._place_chunk(idx, len(chunk))
                groups.setdefault(fid, []).append(
                    (fkey, ckey, chunk.copy() if compact else chunk))
        if self.spill is not None and self.cfg.async_writeback:
            # journal each fragment's pre-EC payload ONCE (zero-copy u8
            # view — the chunks are deterministically derivable) plus a
            # tiny stub frame per chunk record, in one batched append.
            # Replay re-encodes the fragment to regenerate stub chunks
            # and restores the persistent-buffer entry. Stubs follow
            # their fragment in the journal, so a torn tail can only
            # cost stubs of the LAST (necessarily unacked) PUT its
            # fragment record — acked data always survives.
            ckeys = [ckey for items in groups.values()
                     for _, ckey, _ in items]
            seqs = self.spill.append_many(
                [(f"frag/{fk}", frag) for fk, frag in frags]
                + [(f"chunk/{ck}", b"") for ck in ckeys])
            for (fkey, _), seq in zip(frags, seqs):
                self._spill_put_frag_seqs[fkey] = seq
            for ckey, seq in zip(ckeys, seqs[len(frags):]):
                self._spill_put_seqs[ckey] = seq
        # phase 1: slab writes only, so a fragment can still fail before
        # anything about it becomes durable
        failed: Set[str] = set()
        written: Dict[int, List[Tuple[str, str, object]]] = {}
        for fid, items in groups.items():
            slab = self.sms.get(fid)
            self._invoke(fid, sum(len(c) for _, _, c in items), "request")
            for fkey, ckey, chunk in items:
                tfid = fid
                stored = slab.store(ckey, chunk)
                if not stored:
                    # the slab refused what the ledger allowed: batch
                    # placement ran before any write, so _place_chunk's
                    # slab-authority resync (§5.3.1) never saw the bytes
                    # this batch already stored here. Release and
                    # re-place now that slab.used is live.
                    self.placement.release(tfid, len(chunk))
                    idx = int(ckey.rsplit("#", 1)[1])
                    for _ in range(3):
                        tfid = self._place_chunk(idx, len(chunk))
                        tslab = self.sms.get(tfid)
                        self._invoke(tfid, len(chunk), "request")
                        if tslab.store(ckey, chunk):
                            stored = True
                            break
                        self.placement.release(tfid, len(chunk))
                if stored:
                    written.setdefault(tfid, []).append((fkey, ckey, chunk))
                else:
                    failed.add(fkey)
        # phase 2: failed fragments roll their stored chunks back out of
        # the slabs; surviving fragments become visible (chunk_map), are
        # queued for COS persistence (§5.3.2), and land in the insertion
        # log — the durable point
        for fid, items in written.items():
            slab = self.sms.get(fid)
            records: List[PutRecord] = []
            for fkey, ckey, chunk in items:
                if fkey in failed:
                    if slab.delete(ckey):
                        self.placement.release(fid, len(chunk))
                    continue
                with self._lock:
                    self.chunk_map[ckey] = fid
                self._persist_chunk(fkey, ckey, chunk)
                records.append(PutRecord(key=ckey, size=len(chunk),
                                         version=0))
            # consolidate this window's records into insertion nodes
            if records:
                log = self.logs[fid]
                log.append(records)
                slab.term = log.term
                slab.log_hash = log.last_hash
                slab.diff_rank = log.diff_rank
                self.daemon_view[fid] = log.piggyback()
        # failed fragments' pre-appended journal records die here;
        # surviving fragments' records commit (dropped when the buffer
        # entry drains — _on_chunk_persisted / the ack-point release).
        # Only the leftover CHUNK stubs are killed — _spill_abort_chunks
        # would also void the surviving fragments' payload records,
        # losing acked data on a crash (the mixed-failure-batch hole)
        if self._spill_put_seqs:
            seqs, self._spill_put_seqs = self._spill_put_seqs, {}
            for seq in seqs.values():
                self.spill.mark_persisted(seq)
        if self._spill_put_frag_seqs:
            frag_seqs, self._spill_put_frag_seqs = \
                self._spill_put_frag_seqs, {}
            for fkey, seq in frag_seqs.items():
                if fkey in failed:
                    self.spill.mark_persisted(seq)
                else:
                    with self._lock:
                        self._spill_frag_seqs[fkey] = seq
        return failed

    # ------------------------------------------------------------------
    # GET (Appendix A right + §5.3.3)
    # ------------------------------------------------------------------

    def get(self, key: str) -> Optional[bytes]:
        return self.get_async(key).result()

    def get_async(self, key: str) -> StoreFuture:
        """Non-blocking GET; the future resolves to bytes or None."""
        return self._submit(lambda: self._get_many_impl([key])[key])

    def get_many(self, keys) -> Dict[str, Optional[bytes]]:
        return self.get_many_async(keys).result()

    def get_many_async(self, keys) -> StoreFuture:
        """Batch GET: chunk reads are grouped into ONE invoke per function
        across the whole gather, and ALL fragments needing EC
        reconstruction are decoded by a single `decode_many` call. The
        future resolves to {key: value-or-None}."""
        keys = list(keys)
        obs = self._obs
        with (obs.span("client.get_many", n=len(keys))
              if obs is not None else NOOP_CM):
            return self._submit(lambda: self._get_many_impl(keys))

    def get_array(self, key: str) -> Optional[np.ndarray]:
        """GET returning a flat uint8 array (no bytes materialization) —
        the device/checkpoint payload path."""
        return self.get_many_arrays([key])[key]

    def get_many_arrays(self, keys) -> Dict[str, Optional[np.ndarray]]:
        return self.get_many_arrays_async(keys).result()

    def get_many_arrays_async(self, keys) -> StoreFuture:
        keys = list(keys)
        return self._submit(
            lambda: self._get_many_impl(keys, as_arrays=True))

    def _get_many_impl(self, keys, *, as_arrays: bool = False) -> Dict:
        obs = self._obs
        with (obs.span("daemon.get_many", n=len(keys))
              if obs is not None else NOOP_CM):
            if self.cfg.pipelined_get:
                return self._get_many_pipelined(keys, as_arrays=as_arrays)
            return self._get_many_serial(keys, as_arrays=as_arrays)

    def _plan_gets(self, keys, out: Dict):
        """Shared GET planning: resolve metadata, serve read-after-write
        fragments from the persistent buffer, and list the fragment keys
        that need a chunk gather."""
        plans: List[Tuple[str, object, List[object]]] = []
        gather_fkeys: List[str] = []
        for key in keys:
            self.stats.inc("gets")
            m = self._resolve_meta(key)
            if m is None:
                out[key] = None
                continue
            parts: List[object] = []   # payload, or str fkey placeholder
            for fi in range(m.num_fragments):
                fkey = f"{key}|{m.ver}/f{fi}"
                buf = self.pb.load(fkey)             # read-after-write
                if buf is not None:
                    self.stats.inc("buffer_hits")
                    parts.append(buf)
                else:
                    parts.append(fkey)
                    gather_fkeys.append(fkey)
            plans.append((key, m, parts))
        return plans, gather_fkeys

    def _get_many_serial(self, keys, *, as_arrays: bool = False) -> Dict:
        """The legacy GET path (pipelined_get=False, the A/B baseline):
        gather EVERY fragment's chunks — COS fallbacks one chunk at a
        time — then decode everything behind one global barrier."""
        out: Dict = {}
        plans, gather_fkeys = self._plan_gets(dict.fromkeys(keys), out)
        gathered = self._gather_many(gather_fkeys) if gather_fkeys else {}
        batch: List[Dict[int, object]] = []
        final: List[Tuple[str, object, List[object]]] = []
        for key, m, parts in plans:
            resolved: List[object] = []
            for p in parts:
                if isinstance(p, str):               # needs chunk gather
                    chunks = gathered.get(p)
                    if chunks is None:
                        out[key] = None
                        resolved = None
                        break
                    resolved.append(len(batch))
                    batch.append(chunks)
                else:
                    resolved.append(p)
            if resolved is not None:
                # only successful keys reach the decode batch; a failed
                # key's already-gathered fragments are dropped here
                final.append((key, m, resolved))
        decoded = self.codec.decode_many(batch, as_arrays=as_arrays) \
            if batch else []
        for key, m, parts in final:
            pieces = [decoded[p] if isinstance(p, int) else p
                      for p in parts]
            val = self._assemble(pieces, m.size, as_arrays)
            self._track_queue(payload_nbytes(val))
            out[key] = val
        return out

    def _get_many_pipelined(self, keys, *, as_arrays: bool = False) -> Dict:
        """The pipelined GET data path: (1) plan + buffer hits, (2) one
        grouped SMS sweep (at most one invoke per function), (3) every
        still-short fragment's missing chunks fan out to COS on the
        bounded I/O executor AT ONCE, (4) fragments decode in ready-order
        batches while those reads are in flight — decode of fragment A
        overlaps the gather of fragment B instead of a global barrier.
        The sequential-scan prefetcher warms the predicted next objects'
        chunks on the same executor during decode."""
        self._harvest_prefetch()
        out: Dict = {}
        ordered = list(dict.fromkeys(keys))
        plans, gather_fkeys = self._plan_gets(ordered, out)
        if gather_fkeys:
            # readahead is issued inside the gather, AFTER this batch's
            # own demand reads hit the FIFO executor — warms overlap the
            # decode without ever delaying the critical path
            frags = self._gather_decode_pipelined(
                gather_fkeys, as_arrays, prefetch_keys=ordered)
        else:
            frags = {}
            self._maybe_prefetch(ordered)
        for key, m, parts in plans:
            pieces: Optional[List[object]] = []
            for p in parts:
                if isinstance(p, str):
                    p = frags.get(p)
                    if p is None:                    # fragment lost
                        pieces = None
                        break
                pieces.append(p)
            if pieces is None:
                out[key] = None
                continue
            val = self._assemble(pieces, m.size, as_arrays)
            self._track_queue(payload_nbytes(val))
            out[key] = val
        self._sync_prefetch_stats()
        return out

    def _sms_sweep(self, fkeys: Sequence[str],
                   have: Dict[str, Dict[int, object]],
                   degraded_out: List[str]) -> None:
        """The grouped SMS sweep shared by both GET paths: round 0 reads
        the first k mapped chunks per fragment (EC needs only k); round 1
        widens to the remaining mapped chunks for fragments a failed read
        left short. Each round groups reads by function — at most ONE
        invoke per function across the whole sweep."""
        n, k = self.cfg.ec.n, self.cfg.ec.k
        candidates: Dict[str, List[Tuple[int, str, int]]] = {}
        for fkey in fkeys:
            cand = []
            for idx in range(n):
                ckey = f"{fkey}#{idx}"
                fid = self.chunk_map.get(ckey)
                if fid is not None:
                    cand.append((idx, ckey, fid))
            candidates[fkey] = cand
        tried: Set[Tuple[str, int]] = set()
        invoked: Set[int] = set()
        for rnd in (0, 1):
            groups: Dict[int, List[Tuple[str, int, str]]] = {}
            for fkey, cand in candidates.items():
                if len(have[fkey]) >= k:
                    continue
                sel = cand[:k] if rnd == 0 else cand
                for idx, ckey, fid in sel:
                    if (fkey, idx) in tried or idx in have[fkey]:
                        continue
                    tried.add((fkey, idx))
                    groups.setdefault(fid, []).append((fkey, idx, ckey))
            for fid, group in groups.items():
                for fkey, idx, data in self._read_chunks_grouped(
                        fid, group, degraded_out, invoked):
                    have[fkey][idx] = data

    def _gather_decode_pipelined(self, fkeys: Sequence[str],
                                 as_arrays: bool, *,
                                 prefetch_keys: Optional[Sequence[str]]
                                 = None) -> Dict[str, Optional[object]]:
        """fkey -> reconstructed fragment payload (None = unrecoverable).

        Degraded-bucket hits are queued for gc_tick's compaction round
        instead of migrating inline — the read path never blocks on
        maintenance COS I/O. Demand reads reuse in-flight prefetch
        futures rather than duplicating the fetch."""
        n, k = self.cfg.ec.n, self.cfg.ec.k
        fkeys = list(dict.fromkeys(fkeys))
        have: Dict[str, Dict[int, object]] = {f: {} for f in fkeys}
        degraded: List[str] = []
        obs = self._obs
        t0 = time.perf_counter() if obs is not None else 0.0
        self._sms_sweep(fkeys, have, degraded)
        if obs is not None:
            obs.record("get.sms_sweep_us",
                       (time.perf_counter() - t0) * 1e6)
        if degraded:
            self._pending_migrations.update(dict.fromkeys(degraded))
        # stage 2: every short fragment's demand reads fan out at once
        # (bounded by the executor's get_io_workers), all fragments
        # concurrently. Within a fragment the reads go data-row-first:
        # exactly k-|got| missing chunks in index order, so a fully-lost
        # fragment reconstructs via the identity fast path (concat, no
        # GF(256) matmul); the remaining indices (usually parity) stay in
        # reserve and refill one-for-one when a read comes back empty.
        futs: Dict[Future, Tuple[str, int, str]] = {}
        frag_pending: Dict[str, Set[Future]] = {}
        reserve: Dict[str, List[int]] = {}

        def submit(fkey: str, idx: int) -> None:
            ckey = f"{fkey}#{idx}"
            fut = self._prefetch_inflight.pop(ckey, None)
            if fut is None:
                # no readahead in flight for this chunk — issue the read.
                # Adopted warms are counted as hits only when their data
                # actually arrives (stage 3), never at adoption time
                self.stats.inc("cos_fallback_reads")
                fut = self._io.submit(
                    obs.bind_current(self._cos_fetch_task)
                    if obs is not None else self._cos_fetch_task,
                    f"chunk/{ckey}")
            futs[fut] = (fkey, idx, ckey)
            frag_pending.setdefault(fkey, set()).add(fut)

        for fkey in fkeys:
            got = have[fkey]
            if len(got) >= k:
                continue
            missing = [idx for idx in range(n) if idx not in got]
            short = k - len(got)
            reserve[fkey] = missing[short:]
            for idx in missing[:short]:
                submit(fkey, idx)
        if prefetch_keys is not None:
            # readahead enqueues BEHIND this batch's demand reads (FIFO
            # executor): warms fill idle workers during the decode below
            # without ever delaying the critical path
            self._maybe_prefetch(prefetch_keys)
        # stage 3: ready-order decode overlapping the in-flight reads
        out: Dict[str, Optional[object]] = {}
        batch_size = max(1, self.cfg.decode_batch_fragments)
        queue: List[str] = [f for f in fkeys if len(have[f]) >= k]
        settled: Set[str] = set(queue)
        while queue or futs:
            if queue:
                batch, queue = queue[:batch_size], queue[batch_size:]
                td = time.perf_counter() if obs is not None else 0.0
                with (obs.span("get.decode", fragments=len(batch))
                      if obs is not None else NOOP_CM):
                    vals = self.codec.decode_many(
                        [have[f] for f in batch], as_arrays=as_arrays)
                self.stats.inc("decode_batches")
                if obs is not None:
                    obs.record("get.decode_batch_us",
                               (time.perf_counter() - td) * 1e6)
                out.update(zip(batch, vals))
                continue
            ready, _ = wait(list(futs), return_when=FIRST_COMPLETED)
            for fut in ready:
                fkey, idx, ckey = futs.pop(fut)
                frag_pending[fkey].discard(fut)
                try:
                    data = fut.result()
                except OpDeadlineExceeded:
                    # a configured per-op deadline is a caller contract:
                    # it must surface through the GET's StoreFuture, not
                    # silently degrade into a miss
                    raise
                except Exception:                     # noqa: BLE001
                    data = None
                if data is None:
                    # a failed adopted warm counts as waste, not a hit
                    self.prefetcher.discard(ckey)
                    if fkey not in settled and reserve.get(fkey):
                        submit(fkey, reserve[fkey].pop(0))
                else:
                    self.prefetcher.consume(ckey)     # adopted warm: hit
                    # §5.3.3 on-demand migration: cache the chunk even if
                    # its fragment already decoded — the next GET hits SMS
                    self._demand_cache(ckey, data)
                    if fkey not in settled:
                        have[fkey][idx] = data
                        if len(have[fkey]) >= k:
                            settled.add(fkey)
                            queue.append(fkey)
                if fkey not in settled and not frag_pending[fkey]:
                    settled.add(fkey)                 # short for good
                    out[fkey] = None
        for fkey in fkeys:
            out.setdefault(fkey, None)
        return out

    @staticmethod
    def _assemble(pieces: List[object], size: int, as_arrays: bool):
        """Join fragment payloads into the object value, trimmed to the
        metadata size. Array results are READ-ONLY views: a single-
        fragment result can alias the persistent buffer's durable copy,
        and stored objects are immutable by contract anyway."""
        if as_arrays:
            val = pieces[0] if len(pieces) == 1 else \
                np.concatenate([as_u8(p) for p in pieces])
            val = as_u8(val)
            out = (val[:size] if size else val).view()
            out.flags.writeable = False
            return out
        if all(isinstance(p, bytes) for p in pieces):
            val = b"".join(pieces)
        else:
            val = b"".join(to_bytes(p) for p in pieces)
        return val[:size] if size else val

    def _resolve_meta(self, key: str):
        """Follow the version chain to the newest done-ok metadata. A
        head prepared by an uncommitted cross-shard batch is NOT waited
        on (its commit is queued behind this GET on the same daemon):
        uncommitted data is invisible, so the read falls through to the
        previous version immediately."""
        m = self.mt.load(key)
        attempts = 0
        while m is not None and not m.is_done_ok() and attempts < 8:
            if not m.is_done() and not m.prepared:    # concurrent PUT
                m.wait(timeout=5.0)
            if m.is_done_ok():
                break
            if m.prev_ver <= 0:
                return None
            m = self.mt.load(f"{key}|{m.prev_ver}")
            attempts += 1
        if m is None or not m.is_done_ok():
            return None
        return m

    def _gather_many(self, fkeys: Sequence[str]
                     ) -> Dict[str, Optional[Dict[int, object]]]:
        """Gather >= k chunks for every fragment, issuing AT MOST ONE
        invoke per function across the whole gather (the GET-side mirror
        of the PUT-side per-function grouping). The legacy serial path:
        degraded hits migrate inline, COS fallbacks run one chunk at a
        time."""
        n, k = self.cfg.ec.n, self.cfg.ec.k
        have: Dict[str, Dict[int, object]] = {f: {} for f in fkeys}
        degraded: List[str] = []
        self._sms_sweep(fkeys, have, degraded)
        if degraded:
            self._migrate_chunks(degraded)            # sync migration
        out: Dict[str, Optional[Dict[int, object]]] = {}
        for fkey, got in have.items():
            if len(got) < k:
                # on-demand migration from COS (§5.3.3); the pending
                # writeback map covers acked-but-unpersisted chunks
                for idx in range(n):
                    if idx in got:
                        continue
                    ckey = f"{fkey}#{idx}"
                    self.stats.inc("cos_fallback_reads")
                    data = self._cos_read_consistent(f"chunk/{ckey}")
                    if data is not None:
                        got[idx] = data
                        self._demand_cache(ckey, data)
                    if len(got) >= k:
                        break
            out[fkey] = got if len(got) >= k else None
        return out

    def _read_chunks_grouped(self, fid: int,
                             items: List[Tuple[str, int, str]],
                             degraded_out: List[str],
                             invoked: Set[int]) -> List[Tuple[str, int, object]]:
        """Read this function's share of a gather with ONE invoke (and
        one consolidated ledger charge for the bytes served)."""
        out: List[Tuple[str, int, object]] = []
        slab = self.sms.slabs.get(fid)
        if slab is None:                              # function released
            self.stats.inc("sms_chunk_misses", len(items))
            return out
        state = self.window.state_of_function(fid)
        if state is None or state == BucketState.RELEASED:
            self.stats.inc("sms_chunk_misses", len(items))
            return out
        if fid not in invoked:
            self._invoke(fid, 0, "request")
            self.stats.inc("gather_invokes")
            invoked.add(fid)
        nbytes = 0
        for fkey, idx, ckey in items:
            data = self.recovery.serve_during_recovery(fid, ckey)
            if data is None:
                data = slab.load(ckey)
            if data is None:
                self.stats.inc("sms_chunk_misses")
                continue
            self.stats.inc("sms_chunk_hits")
            self.prefetcher.consume(ckey)
            nbytes += len(data)
            # mark re-accessed data for compaction (§5.3.3)
            self.window.mark(ckey)
            if state == BucketState.DEGRADED:
                self.stats.inc("degraded_hits")
                degraded_out.append(ckey)
            out.append((fkey, idx, data))
        if nbytes:
            self.ledger.invoke("request", gb=slab.capacity / 1024**3,
                               seconds=nbytes * self.cfg.busy_per_byte_s)
        return out

    def _cos_read_consistent(self, key: str,
                             max_tries: Optional[int] = None):
        """SCFS-style consistency-increasing loop: retry until the
        eventually-consistent COS shows the object (Appendix A), with
        capped exponential backoff derived from the configured
        `cos_visibility_lag`. Unified with the store's RetryPolicy
        (repro.core.faults): transient/throttle COS errors retry on the
        policy's backoff schedule inside the same attempt budget,
        permanent errors raise immediately, and an optional per-op
        deadline (`cfg.cos_op_deadline_s`) raises OpDeadlineExceeded —
        surfaced through the GET's StoreFuture — instead of burning the
        full budget. Writes still queued for persistence are served
        from the writeback pending map — they're not in COS yet by
        construction. Thread-safe: runs on the daemon thread (legacy
        path) or the GET I/O executor (pipelined fan-out); the ledger is
        charged under the store lock."""
        policy = self.cos_retry
        tries = max_tries if max_tries is not None else \
            policy.max_attempts
        deadline_s = self.cfg.cos_op_deadline_s
        start = time.monotonic()
        for attempt in range(1, tries + 1):
            data = self.writeback.peek(key)
            if data is not None:
                return data
            last_exc = None
            try:
                data = self.cos.get(key)
            except Exception as e:                # noqa: BLE001
                kind = policy.classify(e)
                if kind == RetryPolicy.PERMANENT:
                    raise
                last_exc, data = e, None
            with self._lock:
                self.ledger.cos_op("get")
            if data is not None:
                return data
            if last_exc is not None:              # error backoff
                delay = policy.delay(attempt, policy.classify(last_exc))
            else:                                 # visibility backoff
                delay = min(policy.backoff_base_s * (2.0 ** (attempt - 1)),
                            policy.backoff_cap_s)
            if deadline_s is not None and \
                    time.monotonic() - start + delay > deadline_s:
                raise OpDeadlineExceeded(
                    f"COS read {key!r}: {deadline_s:.3f}s deadline "
                    f"exceeded after {attempt} attempts") from last_exc
            if self.clock.is_wall:
                time.sleep(delay)
            else:
                self.clock.advance(delay)
        return None

    def _cos_fetch_task(self, cos_key: str):
        """I/O-executor body for one demand/prefetch chunk read. Touches
        only thread-safe layers (pending map, COS, clock, ledger under
        the store lock); all store mutation happens back on the daemon
        thread when the future is harvested."""
        obs = self._obs
        if obs is None:
            return self._cos_read_consistent(cos_key)
        t0 = time.perf_counter()
        with obs.span("get.cos_fallback", key=cos_key):
            data = self._cos_read_consistent(cos_key)
        obs.record("get.cos_fallback_us",
                   (time.perf_counter() - t0) * 1e6)
        return data

    # ------------------------------------------------------------------
    # prefetch (sequential-scan readahead)
    # ------------------------------------------------------------------

    def _maybe_prefetch(self, keys: Sequence[str]) -> None:
        """Sequential-scan readahead: predict the next objects of
        detected key runs (checkpoint shard restore, KV page restore —
        ordered trailing-index scans) and warm their non-resident chunks
        from COS into bucket cache space via the I/O executor. The
        fetches run while THIS GET decodes; the next GETs in the scan
        consume them as ordinary SMS cache hits."""
        if not self.prefetcher.cfg.enabled:
            return
        k, n = self.cfg.ec.k, self.cfg.ec.n
        predicted = self.prefetcher.observe(keys)
        for ckey in self.prefetcher.take_dropped():
            # a cancelled/pruned run's warms must not keep occupying the
            # executor ahead of future demand reads
            fut = self._prefetch_inflight.pop(ckey, None)
            if fut is not None:
                fut.cancel()
        for pkey, stem in predicted:
            m = self.mt.load(pkey)
            if m is None or not m.is_done_ok():
                continue                   # unknown or in-flight object
            for fi in range(m.num_fragments):
                fkey = f"{pkey}|{m.ver}/f{fi}"
                if self.pb.load(fkey) is not None:
                    continue               # persistent buffer serves it
                resident = 0
                absent: List[str] = []
                for idx in range(n):
                    ckey = f"{fkey}#{idx}"
                    if ckey in self._prefetch_inflight \
                            or self._chunk_resident(ckey):
                        resident += 1
                    else:
                        absent.append(ckey)
                # warm just enough absent chunks that any k are servable
                for ckey in absent[:max(0, k - resident)]:
                    if len(self._prefetch_inflight) >= \
                            self.cfg.prefetch_max_inflight:
                        return
                    self.prefetcher.record_issued(ckey, stem)
                    self._prefetch_inflight[ckey] = self._io.submit(
                        self._cos_fetch_task, f"chunk/{ckey}")

    def _chunk_resident(self, ckey: str) -> bool:
        """Is this chunk servable from SMS (storage or cache space)?"""
        fid = self.chunk_map.get(ckey)
        if fid is None:
            return False
        state = self.window.state_of_function(fid)
        if state is None or state == BucketState.RELEASED:
            return False
        slab = self.sms.slabs.get(fid)
        return slab is not None and slab.load(ckey) is not None

    def _harvest_prefetch(self) -> None:
        """Apply completed warm fetches (daemon thread only): loaded
        chunks go into bucket cache space + the chunk map, so the next
        GET's grouped SMS sweep serves them as cache hits."""
        if not self._prefetch_inflight:
            return
        done = [ck for ck, f in self._prefetch_inflight.items()
                if f.done()]
        for ckey in done:
            fut = self._prefetch_inflight.pop(ckey)
            try:
                data = fut.result()
            except Exception:                         # noqa: BLE001
                data = None
            if data is None:
                self.prefetcher.discard(ckey)
            else:
                self._demand_cache(ckey, data)

    def _sync_prefetch_stats(self) -> None:
        """Mirror the prefetcher's accounting into StoreStats (one sync
        point per GET / gc_tick instead of per consume/waste site)."""
        self.stats.prefetch_hits = self.prefetcher.stats.hits
        self.stats.prefetch_wasted = self.prefetcher.stats.wasted

    # ------------------------------------------------------------------
    # demand caching + compaction + GC
    # ------------------------------------------------------------------

    def _cache_target_fid(self) -> Optional[int]:
        """A slab to host evictable cache-space bytes WITHOUT forcing a
        scale-out: open-FG slabs first (the latest bucket's cache
        functions, §5.3.3), else any alive ACTIVE-bucket slab. None when
        nothing suitable exists — caching is an optimization, never
        worth spinning up a function group."""
        for fg_id in self.placement.open_fg_ids:
            for fid in self.placement.fgs[fg_id].fids:
                slab = self.sms.slabs.get(fid)
                if slab is not None and slab.alive:
                    return fid
        for fid, slab in self.sms.slabs.items():
            if slab.alive and self.window.state_of_function(fid) \
                    == BucketState.ACTIVE:
                return fid
        return None

    def _demand_cache(self, ckey: str, data) -> None:
        """GET-triggered caching into the latest bucket's cache space
        (§5.3.3 'cache functions'); evictable, not counted against
        HARDCAP, and never a reason to spin up a new function group."""
        fid = self._cache_target_fid()
        if fid is None:
            return
        self.sms.get(fid).cache_put(ckey, data)
        with self._lock:
            self.chunk_map[ckey] = fid
        self.stats.inc("migrations")

    def _migrate_chunks(self, ckeys: List[str]) -> None:
        """Compaction: move marked/hit chunks into the latest GC-bucket by
        loading them from COS into newly placed slots (§5.3.3). Under the
        pipelined GET path this runs from gc_tick, off the read critical
        path. When no open function can take the chunk it is re-marked
        and skipped: read-path maintenance must not force a scale-out
        (`try_place_chunk` never spins up a function group)."""
        for ckey in ckeys:
            if not self.placement.open_fg_ids:
                self.window.mark(ckey)
                continue
            data = self.writeback.peek(f"chunk/{ckey}")
            if data is None:
                try:
                    data = self.cos.get(f"chunk/{ckey}")
                except Exception as e:            # noqa: BLE001
                    if self.cos_retry.classify(e) \
                            == RetryPolicy.PERMANENT:
                        raise
                    # compaction is maintenance: a transient COS error
                    # re-marks the chunk for the next round rather than
                    # stalling gc_tick on a retry loop
                    self.window.mark(ckey)
                    continue
                finally:
                    with self._lock:  # I/O-executor reads charge it too
                        self.ledger.cos_op("get")
            if data is None:
                old = self.chunk_map.get(ckey)
                data = self.sms.slabs[old].load(ckey) if old is not None \
                    and old in self.sms.slabs else None
            if data is None:
                continue
            idx = int(ckey.rsplit("#", 1)[1])
            while True:
                fid = self.placement.try_place_chunk(idx, len(data))
                if fid is None or self.sms.get(fid).used \
                        < self.sms.get(fid).hardcap:
                    break
                # slab is the authority on fullness (§5.3.1): resync the
                # drifted ledger by sealing and probe the next open FG
                self.placement.release(fid, len(data))
                self.placement.seal_fg(self.placement.functions[fid].fg_id)
            if fid is None:
                self.window.mark(ckey)    # retry once capacity opens
                continue
            slab = self.sms.get(fid)
            self._invoke(fid, len(data), "request")
            if not slab.store(ckey, data):
                self.placement.release(fid, len(data))
            else:
                old = self.chunk_map.get(ckey)
                with self._lock:
                    self.chunk_map[ckey] = fid
                if old is not None and old != fid and old in self.sms.slabs:
                    self.sms.get(old).delete(ckey)
                    self.placement.release(old, len(data))
                log = self.logs[fid]
                log.append([PutRecord(key=ckey, size=len(data), version=0)])
                slab.term, slab.log_hash, slab.diff_rank = \
                    log.term, log.last_hash, log.diff_rank
                self.daemon_view[fid] = log.piggyback()
                self.window.unmark(ckey)
                self.stats.inc("compactions")

    def gc_tick(self) -> None:
        """Run due GC + one compaction round + warmups + a writeback
        drain slice. Call periodically (the serving engine ticks this;
        tests drive the clock). Runs on the client-daemon thread so it
        serializes with in-flight async PUT/GETs."""
        self._submit(self._gc_tick_impl).result()

    def _gc_tick_impl(self) -> None:
        self._harvest_prefetch()
        self._sync_prefetch_stats()
        if self._pending_migrations:
            # degraded-read compaction deferred by the pipelined GET path
            pending = list(self._pending_migrations)
            self._pending_migrations.clear()
            self._migrate_chunks(pending)
        if self.window.due():
            ev = self.window.run_gc()
            # carry open FGs into the new bucket (Fig. 4c)
            for fg_id in self.placement.carry_over_open_fgs():
                for fid in self.placement.fgs[fg_id].fids:
                    ev.new_bucket.add_function(fid, fg_id)
            for fid in ev.released_functions:
                slab = self.sms.slabs.get(fid)
                if slab is not None:
                    slab.reclaim()                    # provider reclaims
        round_keys = self.window.take_compaction_round(self.rng)
        if round_keys:
            self._migrate_chunks(round_keys)
        self._warmup_tick()
        if self.cfg.async_writeback:
            self.writeback.drain(32)                  # §5.3.2 retry point
        # expire temporary recovery placements past retain_seconds (§5.5.2)
        self.recovery.sweep_expired(self.clock.now())
        # provider-side reclamation of long-idle instances
        self.sms.reclaim_idle(self.cfg.provider_idle_reclaim)
        # size-bounded metadata log: fold accumulated meta records +
        # tombstones into one snapshot at a new journal generation
        self._maybe_snapshot_meta()
        if self.spill is not None:
            # group-commit any journal frames the tick produced
            # (migration/compaction insertion-log appends)
            self.spill.sync()

    def _warmup_tick(self) -> None:
        """No-op heartbeat per FMP: active buckets every active_warmup,
        degraded every degraded_warmup (§5.3)."""
        now = self.clock.now()
        for fid, slab in self.sms.slabs.items():
            period = self.window.warmup_period(fid)
            if period is None or not slab.alive:
                continue
            if now - slab.last_invoked >= period:
                slab.invoke(0.001)
                self.ledger.invoke("warmup", gb=slab.capacity / 1024**3,
                                   seconds=0.001)

    # ------------------------------------------------------------------
    # misc
    # ------------------------------------------------------------------

    def _track_queue(self, nbytes: int) -> None:
        if nbytes <= self.cfg.small_request_bytes:
            self.stats.inc("small_requests")
        else:
            self.stats.inc("large_requests")

    def inject_failure(self, fid: int) -> None:
        """Simulate provider reclaiming an instance (tests/benchmarks)."""
        self.sms.get(fid).reclaim()

    def num_functions(self, state: Optional[BucketState] = None) -> int:
        if state is None:
            return len(self.sms.slabs)
        return sum(len(b.function_ids)
                   for b in self.window.buckets(state))

    def health(self) -> dict:
        """Operator-facing health summary: the writeback queue's state
        machine (OK vs DEGRADED_WRITEBACK with its outage evidence),
        permanently-failed (data-at-risk) keys, and any 2PC tickets
        still in doubt. Racy-read consistency like every other stats
        surface — safe from any thread."""
        wb = self.writeback.health()
        self.stats.writeback_permanent_failures = wb["permanent_failures"]
        return {"state": wb["state"],
                "writeback": wb,
                "indoubt_tickets": sorted(
                    set(self._indoubt) | set(self._prepared_tickets)),
                "spill_pending": self.spill.pending_count
                if self.spill is not None else 0}

    def snapshot_metadata(self):
        """Point-in-time view of the daemon's tables and counters.

        Consistency model: every counter read is individually atomic
        (see `StoreStats`), but the snapshot is NOT a consistent cut —
        it is assembled without the store lock while the daemon, the
        writeback writer, and GET I/O workers keep mutating, so
        counters may be mutually skewed by whatever was in flight.
        Structural maps (`mt`, `chunk_map`) are copied under their own
        locks and are internally consistent."""
        with self._lock:
            meta_records = sum(1 for s in self._spill_meta_seqs.values()
                               if s != _SNAP_COVERED)
            snap_covered = len(self._spill_meta_seqs) - meta_records
            tombstones = len(self._spill_tombstones)
        # ONE counter snapshot feeds every derived field below — each
        # reported ratio is internally consistent instead of re-reading
        # live counters per term (see StoreStats.derived)
        stats = self.stats.as_dict()
        return {"mt": self.mt.snapshot(),
                "health": self.health(),
                "chunk_map": dict(self.chunk_map),
                "stats": stats,
                "derived": StoreStats.derived(stats),
                "get_pipeline": {
                    "pipelined": self.cfg.pipelined_get,
                    "prefetch_hits": stats["prefetch_hits"],
                    "prefetch_wasted": stats["prefetch_wasted"],
                    "cos_fallback_reads": stats["cos_fallback_reads"],
                    "decode_batches": stats["decode_batches"],
                    "pending_migrations": len(self._pending_migrations),
                    "prefetch": self.prefetcher.snapshot()},
                "meta_log": {
                    "individual_records": meta_records,
                    "snapshot_covered": snap_covered,
                    "tombstones": tombstones,
                    "snapshots_taken": stats["spill_meta_snapshots"],
                    "generation": self.spill.generation
                    if self.spill is not None else None},
                "spill": self.spill.snapshot()
                if self.spill is not None else None}

    # ------------------------------------------------------------------
    # observability export (repro.obs)
    # ------------------------------------------------------------------

    @property
    def obs(self) -> Optional[ObsPlane]:
        return self._obs

    def snapshot_metrics(self) -> Dict:
        """The unified observability export: latency histograms with
        p50/p99/p999, recent spans, flight-recorder events, recovered
        forensics, plus the store counters (one `as_dict` pass). With no
        (or a disabled) plane attached only the counters carry data —
        same shape either way, so exporters need no special case."""
        plane = self._obs
        snap = dict(plane.snapshot()) if plane is not None \
            else {"enabled": False, "histograms": {}, "spans": [],
                  "events": [], "forensics": []}
        snap["counters"] = self.stats.as_dict()
        return snap

    def dump_metrics(self, path: str) -> str:
        """Write `snapshot_metrics()` to `path` — Prometheus text, or
        JSON when the path ends in `.json`. Returns the path. (The
        `ISTORE_METRICS_DUMP` env var arranges the same dump from an
        atexit hook, covering every live plane in the process.)"""
        snap = self.snapshot_metrics()
        if path.endswith(".json"):
            dump_json(snap, path)
        else:
            with open(path, "w") as f:
                f.write(to_prometheus(snap))
        return path


class ConcurrentPutError(RuntimeError):
    def __init__(self, key: str):
        self.key = key
        super().__init__(f"concurrent PUT in flight for {key!r}; retry")

    def __reduce__(self):
        # crosses the worker->parent control pipe: rebuild from the key
        # (the default Exception reduce would re-wrap the formatted
        # message as a new key)
        return (ConcurrentPutError, (self.key,))
