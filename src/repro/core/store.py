"""InfiniStore facade: GET/PUT over the SMS + COS layers (paper §5).

Wires together: CAS versioning + persistent buffer (Appendix A), RS
erasure coding, PlaceChunk over the sliding-window GC-buckets, insertion
logs, failure detection + local/parallel recovery, demand caching,
compaction, large-object fragmentation, the two-queue scheme, and
pay-per-access cost accounting.

This is the control plane ("client daemon"); payloads are bytes. The
serving/checkpoint layers put device-backed data through the same paths.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.clock import Clock
from repro.core.cos import COS
from repro.core.costmodel import CostLedger
from repro.core.ec import ECConfig, RSCodec
from repro.core.gc_window import BucketState, GCConfig, SlidingWindow
from repro.core.insertion_log import InsertionLog, Piggyback, PutRecord
from repro.core.placement import PlacementManager
from repro.core.recovery import RecoveryManager
from repro.core.sms import SMS
from repro.core.versioning import MetadataTable, PersistentBuffer

MB = 1024 * 1024


@dataclass
class StoreConfig:
    ec: ECConfig = field(default_factory=ECConfig)       # RS(10+2)
    function_capacity: int = 1536 * MB                   # Lambda memory
    fragment_bytes: int = 200 * MB                       # §5.3.4
    small_request_bytes: int = 1 * MB                    # two-queue split
    gc: GCConfig = field(default_factory=GCConfig)
    num_recovery_functions: int = 20
    enable_recovery: bool = True       # False = SNR ablation (Fig. 22/23)
    provider_idle_reclaim: float = 3600.0                # FaaS reclamation
    cos_visibility_lag: float = 0.0
    autoscale: str = "linear"
    # estimated per-request function busy time model (seconds/byte + base),
    # calibrated to the paper's ~75 MB/s per-instance bandwidth
    busy_base_s: float = 0.001
    busy_per_byte_s: float = 1.0 / (75 * MB)


@dataclass
class StoreStats:
    puts: int = 0
    gets: int = 0
    sms_chunk_hits: int = 0
    sms_chunk_misses: int = 0
    buffer_hits: int = 0
    migrations: int = 0
    compactions: int = 0
    degraded_hits: int = 0
    small_requests: int = 0
    large_requests: int = 0

    @property
    def hit_ratio(self) -> float:
        tot = self.sms_chunk_hits + self.sms_chunk_misses
        return self.sms_chunk_hits / tot if tot else 0.0


class InfiniStore:
    def __init__(self, cfg: Optional[StoreConfig] = None, *,
                 clock: Optional[Clock] = None,
                 cos_root: Optional[str] = None, seed: int = 0):
        # NOTE: cfg default must be constructed per-instance — a dataclass
        # default in the signature would be shared (and cross-mutated)
        # between every default-constructed store.
        self.cfg = cfg = cfg if cfg is not None else StoreConfig()
        self.clock = clock or Clock()
        self.cos = COS(self.clock, visibility_lag=cfg.cos_visibility_lag,
                       root=cos_root)
        self.sms = SMS(self.clock)
        self.window = SlidingWindow(cfg.gc, self.clock)
        self.codec = RSCodec(cfg.ec)
        self.mt = MetadataTable()
        self.pb = PersistentBuffer()
        self.logs: Dict[int, InsertionLog] = {}
        self.ledger = CostLedger()
        self.stats = StoreStats()
        self.rng = np.random.default_rng(seed)
        self._lock = threading.RLock()
        # chunk key -> function id (the daemon's chunk-function mapping)
        self.chunk_map: Dict[str, int] = {}
        # daemon's piggybacked view of each function's insertion state
        self.daemon_view: Dict[int, Piggyback] = {}
        from repro.core.sms import hardcap
        self.placement = PlacementManager(
            cfg.ec.n, hardcap(cfg.function_capacity),
            autoscale=cfg.autoscale,
            new_function_cb=self._on_new_function)
        self.recovery = RecoveryManager(
            self.sms, self.cos, self.logs,
            num_recovery_functions=cfg.num_recovery_functions)
        self._pending_records: Dict[int, List[PutRecord]] = {}

    # ------------------------------------------------------------------
    # function lifecycle
    # ------------------------------------------------------------------

    def _on_new_function(self, fid: int, fg_id: int, capacity: int) -> None:
        self.sms.add(fid, capacity)
        self.logs[fid] = InsertionLog(fid, self.cos)
        self.daemon_view[fid] = Piggyback()
        self.window.latest.add_function(fid, fg_id)
        self.recovery.assign_group(fid, list(self.sms.slabs.keys()))

    def _invoke(self, fid: int, nbytes: int, category: str) -> None:
        """Invoke a function instance: failure detection happens here, on
        invocation, exactly as in the paper (§5.5.1)."""
        slab = self.sms.get(fid)
        busy = self.cfg.busy_base_s + nbytes * self.cfg.busy_per_byte_s
        was_dead = not slab.alive
        slab.invoke(busy)
        gb = slab.capacity / (1024 ** 3)
        self.ledger.invoke(category, gb=gb, seconds=busy)
        view = self.daemon_view.get(fid, Piggyback())
        failed = self.recovery.check_failed(slab, view) or was_dead
        if failed and view.term > 0 and self.cfg.enable_recovery:
            self._recover(fid)

    def _recover(self, fid: int) -> None:
        slab = self.sms.get(fid)
        view = self.daemon_view[fid]
        candidates = [f for f in self.sms.slabs
                      if self.window.state_of_function(f)
                      == BucketState.ACTIVE]
        t0 = self.clock.now()
        if self.recovery.needs_parallel(slab, view):
            session = self.recovery.recover_parallel(slab, candidates)
            nbytes = sum(len(v) for v in session.recovered.values())
            for rfid in session.group:
                self.ledger.invoke("recovery",
                                   gb=self.sms.get(rfid).capacity / 1024**3,
                                   seconds=self.cfg.busy_base_s
                                   + nbytes / max(len(session.group), 1)
                                   * self.cfg.busy_per_byte_s)
        else:
            n = self.recovery.recover_local(slab)
            self.ledger.invoke("recovery", gb=slab.capacity / 1024**3,
                               seconds=self.cfg.busy_base_s
                               + n * self.cfg.busy_per_byte_s * 1024)
        del t0

    # ------------------------------------------------------------------
    # PUT (Appendix A left + §5.3.1/§5.3.2)
    # ------------------------------------------------------------------

    def put(self, key: str, value: bytes) -> int:
        """Strongly-consistent versioned PUT. Returns the version."""
        return self.put_many([(key, value)], raise_on_conflict=True)[key]

    def put_many(self, items, *, raise_on_conflict: bool = False
                 ) -> Dict[str, int]:
        """Batch PUT: one CAS per key, but ALL fragments of ALL objects go
        through a single `encode_many` codec call and chunk writes are
        grouped per function (one invoke + one insertion-log append each).
        items: dict or iterable of (key, value). Returns {key: version}
        (-1 on failure), matching `put` per key. A CAS conflict on one key
        fails only that key (-1) unless raise_on_conflict (the single-key
        `put` contract: raise so the caller retries)."""
        items = list(items.items()) if isinstance(items, dict) \
            else list(items)
        if len({k for k, _ in items}) != len(items):
            # a duplicate key would CAS against its own in-flight version
            raise ValueError("duplicate keys in put_many batch")
        conflicted: List[str] = []
        metas: List[Tuple[str, object, int, List[str]]] = []
        frags: List[Tuple[str, bytes]] = []
        try:
            for key, value in items:
                self.stats.puts += 1
                self._track_queue(len(value))
                c = self.mt.prepare(key, 1)
                try:
                    while True:
                        m, ok = self.mt.cas(key, c)
                        if ok:
                            break
                        if not m.is_done():
                            m.wait(timeout=5.0)
                            raise ConcurrentPutError(key)
                        c.revise(m.ver + 1)
                except ConcurrentPutError:
                    # candidate never installed -> nothing to clean up;
                    # other keys in the batch proceed independently
                    if raise_on_conflict:
                        raise
                    conflicted.append(key)
                    continue
                ver = c.ver
                self.mt.store(f"{key}|{ver}", c)
                # register for cleanup BEFORE fragmenting: once the CAS
                # installed c as the head, any failure below must still
                # finalize this key (fkeys is mutated in place)
                fkeys: List[str] = []
                metas.append((key, c, ver, fkeys))
                fragments = [value[i:i + self.cfg.fragment_bytes]
                             for i in range(0, max(len(value), 1),
                                            self.cfg.fragment_bytes)]
                c.num_fragments = len(fragments)
                c.size = len(value)
                for fi, frag in enumerate(fragments):
                    fkey = f"{key}|{ver}/f{fi}"
                    self.pb.create(fkey, frag)      # persistent buffer
                    fkeys.append(fkey)
                    frags.append((fkey, frag))
            failed = self._put_fragments(frags)
            # PUT returns after SMS insertion; COS persistence is async
            # and retried from the persistent buffer (§5.3.2). Here the
            # insertion log append IS the durable point, buffers release.
            out: Dict[str, int] = {}
            for key, c, ver, fkeys in metas:
                for fkey in fkeys:
                    self.pb.release(fkey)
                ok = c.done(not any(fk in failed for fk in fkeys))
                if ok and c.prev_ver > 0:
                    self._gc_old_version(key, c.prev_ver)
                out[key] = ver if ok else -1
        except BaseException:
            # finalize every CAS-installed key that hasn't completed as
            # failed so no metadata head stays PENDING forever (readers
            # would block and later puts would raise on every attempt) —
            # covers CAS conflicts, encode/placement errors, MemoryError
            for _, c, _, fkeys in metas:
                if not c.is_done():
                    for fkey in fkeys:
                        self.pb.release(fkey)
                    c.done(False)
            raise
        for key in conflicted:
            out[key] = -1
        return out

    def _gc_old_version(self, key: str, ver: int) -> None:
        """Free the superseded version's SMS chunks (COS retains them for
        any concurrent reader still on the old version)."""
        m = self.mt.load(f"{key}|{ver}")
        nfrags = m.num_fragments if m is not None else 1
        for fi in range(nfrags):
            for idx in range(self.cfg.ec.n):
                ckey = f"{key}|{ver}/f{fi}#{idx}"
                fid = self.chunk_map.pop(ckey, None)
                if fid is not None and fid in self.sms.slabs:
                    slab = self.sms.get(fid)
                    data = slab.load(ckey)
                    if slab.delete(ckey) and data is not None:
                        self.placement.release(fid, len(data))
                self.window.unmark(ckey)

    def _place_chunk(self, idx: int, nbytes: int) -> int:
        """PlaceChunk with the SLAB as the authority on fullness: if the
        placement ledger drifted (migrations/recovery add slab bytes it
        doesn't see), seal the FG to resync and probe on."""
        while True:
            fid = self.placement.place_chunk(idx, nbytes)
            slab = self.sms.get(fid)
            if slab.used < slab.hardcap:
                return fid
            self.placement.seal_fg(self.placement.functions[fid].fg_id)

    def _put_fragments(self, frags: List[Tuple[str, bytes]]) -> Set[str]:
        """Encode ALL fragments in one `encode_many` call, place every
        chunk, then drain the writes grouped by target function: one
        `_invoke` covering the function's whole byte share (amortizing the
        per-request busy-time base of the billing model, §5.2) and one
        insertion-log append per function (§5.5.1). Returns the set of
        fragment keys whose chunks failed to store."""
        if not frags:
            return set()
        all_chunks = self.codec.encode_many([frag for _, frag in frags])
        groups: Dict[int, List[Tuple[str, str, bytes]]] = {}
        for (fkey, _), chunks in zip(frags, all_chunks):
            for idx, chunk in enumerate(chunks):
                ckey = f"{fkey}#{idx}"
                fid = self._place_chunk(idx, len(chunk))
                groups.setdefault(fid, []).append((fkey, ckey, chunk))
        # phase 1: slab writes only, so a fragment can still fail before
        # anything about it becomes durable
        failed: Set[str] = set()
        written: Dict[int, List[Tuple[str, str, bytes]]] = {}
        for fid, items in groups.items():
            slab = self.sms.get(fid)
            self._invoke(fid, sum(len(c) for _, _, c in items), "request")
            for fkey, ckey, chunk in items:
                tfid = fid
                stored = slab.store(ckey, chunk)
                if not stored:
                    # the slab refused what the ledger allowed: batch
                    # placement ran before any write, so _place_chunk's
                    # slab-authority resync (§5.3.1) never saw the bytes
                    # this batch already stored here. Release and
                    # re-place now that slab.used is live.
                    self.placement.release(tfid, len(chunk))
                    idx = int(ckey.rsplit("#", 1)[1])
                    for _ in range(3):
                        tfid = self._place_chunk(idx, len(chunk))
                        tslab = self.sms.get(tfid)
                        self._invoke(tfid, len(chunk), "request")
                        if tslab.store(ckey, chunk):
                            stored = True
                            break
                        self.placement.release(tfid, len(chunk))
                if stored:
                    written.setdefault(tfid, []).append((fkey, ckey, chunk))
                else:
                    failed.add(fkey)
        # phase 2: failed fragments roll their stored chunks back out of
        # the slabs; surviving fragments become visible (chunk_map), hit
        # COS (§5.2), and land in the insertion log — the durable point
        for fid, items in written.items():
            slab = self.sms.get(fid)
            records: List[PutRecord] = []
            for fkey, ckey, chunk in items:
                if fkey in failed:
                    if slab.delete(ckey):
                        self.placement.release(fid, len(chunk))
                    continue
                with self._lock:
                    self.chunk_map[ckey] = fid
                self.cos.put(f"chunk/{ckey}", chunk)
                self.ledger.cos_op("put")
                records.append(PutRecord(key=ckey, size=len(chunk),
                                         version=0))
            # consolidate this window's records into insertion nodes
            if records:
                log = self.logs[fid]
                log.append(records)
                slab.term = log.term
                slab.log_hash = log.last_hash
                slab.diff_rank = log.diff_rank
                self.daemon_view[fid] = log.piggyback()
        return failed

    # ------------------------------------------------------------------
    # GET (Appendix A right + §5.3.3)
    # ------------------------------------------------------------------

    def get(self, key: str) -> Optional[bytes]:
        return self.get_many([key])[key]

    def get_many(self, keys) -> Dict[str, Optional[bytes]]:
        """Batch GET: chunk reads happen per fragment, but ALL fragments
        needing EC reconstruction across the whole batch are decoded by a
        single `decode_many` call (shared survivor sets stack into one
        cached-inverse matmul). Returns {key: value-or-None}."""
        out: Dict[str, Optional[bytes]] = {}
        plans: List[Tuple[str, object, List[object]]] = []
        batch: List[Dict[int, bytes]] = []
        for key in dict.fromkeys(keys):    # dedup, keep first-seen order
            self.stats.gets += 1
            m = self._resolve_meta(key)
            if m is None:
                out[key] = None
                continue
            parts: List[object] = []     # bytes, or int index into `batch`
            local: List[Dict[int, bytes]] = []
            for fi in range(m.num_fragments):
                fkey = f"{key}|{m.ver}/f{fi}"
                buf = self.pb.load(fkey)             # read-after-write
                if buf is not None:
                    self.stats.buffer_hits += 1
                    parts.append(buf)
                    continue
                chunks = self._gather_fragment_chunks(fkey)
                if chunks is None:
                    out[key] = None
                    parts = None
                    break
                parts.append(len(batch) + len(local))
                local.append(chunks)
            if parts is not None:
                # only successful keys reach the decode batch; a failed
                # key's already-gathered fragments are dropped here
                batch.extend(local)
                plans.append((key, m, parts))
        decoded = self.codec.decode_many(batch) if batch else []
        for key, m, parts in plans:
            val = b"".join(p if isinstance(p, bytes) else decoded[p]
                           for p in parts)
            self._track_queue(len(val))
            out[key] = val[:m.size] if m.size else val
        return out

    def _resolve_meta(self, key: str):
        """Follow the version chain to the newest done-ok metadata."""
        m = self.mt.load(key)
        attempts = 0
        while m is not None and not m.is_done_ok() and attempts < 8:
            if not m.is_done():                       # concurrent PUT
                m.wait(timeout=5.0)
            if m.is_done_ok():
                break
            if m.prev_ver <= 0:
                return None
            m = self.mt.load(f"{key}|{m.prev_ver}")
            attempts += 1
        if m is None or not m.is_done_ok():
            return None
        return m

    def _gather_fragment_chunks(self, fkey: str) -> Optional[Dict[int, bytes]]:
        n, k = self.cfg.ec.n, self.cfg.ec.k
        have: Dict[int, bytes] = {}
        missing: List[int] = []
        for idx in range(n):
            ckey = f"{fkey}#{idx}"
            fid = self.chunk_map.get(ckey)
            if fid is None:
                missing.append(idx)
                continue
            data = self._read_chunk(ckey, fid)
            if data is not None:
                have[idx] = data
                if len(have) >= k:
                    break                            # EC: k chunks suffice
            else:
                missing.append(idx)
        if len(have) < k:
            # on-demand migration from COS (§5.3.3)
            for idx in missing:
                ckey = f"{fkey}#{idx}"
                data = self._cos_read_consistent(f"chunk/{ckey}")
                if data is not None:
                    have[idx] = data
                    self._demand_cache(ckey, data)
                if len(have) >= k:
                    break
        if len(have) < k:
            return None
        return have

    def _read_chunk(self, ckey: str, fid: int) -> Optional[bytes]:
        slab = self.sms.slabs.get(fid)
        if slab is None:                              # function released
            self.stats.sms_chunk_misses += 1
            return None
        state = self.window.state_of_function(fid)
        if state is None or state == BucketState.RELEASED:
            self.stats.sms_chunk_misses += 1
            return None
        self._invoke(fid, 0, "request")
        data = self.recovery.serve_during_recovery(fid, ckey)
        if data is None:
            data = slab.load(ckey)
        if data is None:
            self.stats.sms_chunk_misses += 1
            return None
        self.stats.sms_chunk_hits += 1
        self.ledger.invoke("request", gb=slab.capacity / 1024**3,
                           seconds=len(data) * self.cfg.busy_per_byte_s)
        # mark re-accessed data for compaction (§5.3.3)
        self.window.mark(ckey)
        if state == BucketState.DEGRADED:
            self.stats.degraded_hits += 1
            self._migrate_chunks([ckey])              # sync migration
        return data

    def _cos_read_consistent(self, key: str, max_tries: int = 16
                             ) -> Optional[bytes]:
        """SCFS-style consistency-increasing loop: retry until the
        eventually-consistent COS shows the object (Appendix A)."""
        for _ in range(max_tries):
            data = self.cos.get(key)
            self.ledger.cos_op("get")
            if data is not None:
                return data
            if self.clock.is_wall:
                import time
                time.sleep(0.005)
            else:
                self.clock.advance(max(self.cfg.cos_visibility_lag / 4,
                                       0.001))
        return None

    # ------------------------------------------------------------------
    # demand caching + compaction + GC
    # ------------------------------------------------------------------

    def _demand_cache(self, ckey: str, data: bytes) -> None:
        """GET-triggered caching into the latest bucket's cache space
    (§5.3.3 'cache functions'); evictable, not counted against HARDCAP."""
        fid = self.placement.get_open_funcs(0)[0]
        self.sms.get(fid).cache_put(ckey, data)
        with self._lock:
            self.chunk_map[ckey] = fid
        self.stats.migrations += 1

    def _migrate_chunks(self, ckeys: List[str]) -> None:
        """Compaction: move marked/hit chunks into the latest GC-bucket by
        loading them from COS into newly placed slots (§5.3.3)."""
        for ckey in ckeys:
            data = self.cos.get(f"chunk/{ckey}")
            self.ledger.cos_op("get")
            if data is None:
                old = self.chunk_map.get(ckey)
                data = self.sms.slabs[old].load(ckey) if old is not None \
                    and old in self.sms.slabs else None
            if data is None:
                continue
            idx = int(ckey.rsplit("#", 1)[1])
            fid = self._place_chunk(idx, len(data))
            slab = self.sms.get(fid)
            self._invoke(fid, len(data), "request")
            if slab.store(ckey, data):
                old = self.chunk_map.get(ckey)
                with self._lock:
                    self.chunk_map[ckey] = fid
                if old is not None and old != fid and old in self.sms.slabs:
                    self.sms.get(old).delete(ckey)
                    self.placement.release(old, len(data))
                log = self.logs[fid]
                log.append([PutRecord(key=ckey, size=len(data), version=0)])
                slab.term, slab.log_hash, slab.diff_rank = \
                    log.term, log.last_hash, log.diff_rank
                self.daemon_view[fid] = log.piggyback()
                self.window.unmark(ckey)
                self.stats.compactions += 1

    def gc_tick(self) -> None:
        """Run due GC + one compaction round + warmups. Call periodically
        (the serving engine ticks this; tests drive the clock)."""
        if self.window.due():
            ev = self.window.run_gc()
            # carry open FGs into the new bucket (Fig. 4c)
            for fg_id in self.placement.carry_over_open_fgs():
                for fid in self.placement.fgs[fg_id].fids:
                    ev.new_bucket.add_function(fid, fg_id)
            for fid in ev.released_functions:
                slab = self.sms.slabs.get(fid)
                if slab is not None:
                    slab.reclaim()                    # provider reclaims
        round_keys = self.window.take_compaction_round(self.rng)
        if round_keys:
            self._migrate_chunks(round_keys)
        self._warmup_tick()
        # provider-side reclamation of long-idle instances
        self.sms.reclaim_idle(self.cfg.provider_idle_reclaim)

    def _warmup_tick(self) -> None:
        """No-op heartbeat per FMP: active buckets every active_warmup,
        degraded every degraded_warmup (§5.3)."""
        now = self.clock.now()
        for fid, slab in self.sms.slabs.items():
            period = self.window.warmup_period(fid)
            if period is None or not slab.alive:
                continue
            if now - slab.last_invoked >= period:
                slab.invoke(0.001)
                self.ledger.invoke("warmup", gb=slab.capacity / 1024**3,
                                   seconds=0.001)

    # ------------------------------------------------------------------
    # misc
    # ------------------------------------------------------------------

    def _track_queue(self, nbytes: int) -> None:
        if nbytes <= self.cfg.small_request_bytes:
            self.stats.small_requests += 1
        else:
            self.stats.large_requests += 1

    def inject_failure(self, fid: int) -> None:
        """Simulate provider reclaiming an instance (tests/benchmarks)."""
        self.sms.get(fid).reclaim()

    def num_functions(self, state: Optional[BucketState] = None) -> int:
        if state is None:
            return len(self.sms.slabs)
        return sum(len(b.function_ids)
                   for b in self.window.buckets(state))

    def snapshot_metadata(self):
        return {"mt": self.mt.snapshot(),
                "chunk_map": dict(self.chunk_map)}


class ConcurrentPutError(RuntimeError):
    def __init__(self, key: str):
        super().__init__(f"concurrent PUT in flight for {key!r}; retry")
