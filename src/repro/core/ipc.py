"""Shared-memory IPC transport for the multi-process shard host.

One `ShmArena` is a single-writer / single-reader FIFO byte ring over a
`multiprocessing.shared_memory.SharedMemory` segment.  The writer side
allocates contiguous slots (`alloc`), the reader side maps them back to
zero-copy numpy views (`view`), and consumption is acknowledged with
monotonic release watermarks carried on the control pipe
(`release_to`).  Positions are monotonic byte offsets — never wrapped —
so a watermark is unambiguous even after the ring has cycled many
times; a slot that would straddle the physical end of the segment is
pushed past the wrap point by a pad (the pad bytes sit *below* the slot
position, so releasing `pos + length` frees them too).

Payloads larger than the arena (or with no arena at all) fall back to
inline bytes on the control pipe — slower, but always correct.

Python 3.10's ``SharedMemory`` registers segments with the per-process
``resource_tracker`` on *attach*, not just create; a SIGKILLed worker's
tracker would then unlink segments the parent still owns.  `attach`
therefore unregisters immediately after attaching — the creating parent
remains the single owner responsible for unlinking.
"""
from __future__ import annotations

import secrets
import threading
from multiprocessing import resource_tracker, shared_memory
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .locks import make_lock

__all__ = [
    "ArenaBroken",
    "ShmArena",
    "pack_payload",
    "unpack_payload",
]

# Payload descriptors crossing the control pipe:
#   ("a", pos, nbytes)  value lives in the arena at monotonic pos
#   ("i", bytes)        inline fallback (arena-less or oversized)
PayloadDesc = Tuple


class ArenaBroken(ConnectionError):
    """The peer died (or the arena was closed) while data was in flight."""


def _attach_untracked(name: str) -> shared_memory.SharedMemory:
    # Suppress the attach-side register (module attr patch: 3.10's
    # shared_memory calls `resource_tracker.register`). A
    # register+unregister pair would instead DELETE the creator's entry
    # — the tracker cache is one shared name-set — leaving a KeyError
    # at unlink and no crash coverage for the segment.
    orig = resource_tracker.register
    resource_tracker.register = lambda *a, **k: None
    try:
        return shared_memory.SharedMemory(name=name)
    finally:
        resource_tracker.register = orig


class ShmArena:
    """Bounded FIFO byte ring in shared memory (one writer, one reader)."""

    def __init__(self, shm: shared_memory.SharedMemory, size: int, *,
                 owner: bool):
        self._shm = shm
        self.size = int(size)
        self.name = shm.name
        self._owner = owner
        self._buf = np.frombuffer(shm.buf, dtype=np.uint8, count=self.size)
        # Writer-side state only; the reader never touches these.
        self._lock = make_lock("ipc.ShmArena._lock")
        self._space = threading.Condition(self._lock)
        self._head = 0          # next byte to allocate (monotonic)
        self._tail = 0          # all bytes below this are free (monotonic)
        self._broken: Optional[BaseException] = None
        self._closed = False

    # -- lifecycle ---------------------------------------------------------

    @classmethod
    def create(cls, size: int, *, tag: str = "arena") -> "ShmArena":
        name = f"infinistore-{tag}-{secrets.token_hex(6)}"
        shm = shared_memory.SharedMemory(name=name, create=True, size=size)
        return cls(shm, size, owner=True)

    @classmethod
    def attach(cls, name: str, size: int) -> "ShmArena":
        return cls(_attach_untracked(name), size, owner=False)

    def fail(self, exc: BaseException) -> None:
        """Mark the arena broken and wake any blocked allocator."""
        with self._space:
            if self._broken is None:
                self._broken = exc
            self._space.notify_all()

    @property
    def broken(self) -> bool:
        """True once `fail()`/`close()` has condemned the arena — the
        transport health probe, without touching allocator state."""
        with self._space:
            return self._broken is not None

    def close(self) -> None:
        with self._space:
            self._closed = True
            if self._broken is None:
                self._broken = ArenaBroken(f"arena {self.name} closed")
            self._space.notify_all()
        self._buf = None
        try:
            self._shm.close()
        except Exception:
            pass
        if self._owner:
            try:
                self._shm.unlink()
            except Exception:
                pass

    # -- writer side -------------------------------------------------------

    def alloc(self, nbytes: int, *,
              timeout: Optional[float] = None) -> Tuple[int, np.ndarray]:
        """Reserve `nbytes` contiguous bytes; returns (pos, writable view).

        Blocks while the ring is full, until the reader releases space
        (`release_to`) or the arena breaks.  Raises ``ValueError`` when
        the request can never fit — callers fall back to inline bytes.
        """
        n = int(nbytes)
        if n > self.size:
            raise ValueError(f"{n} bytes exceeds arena capacity {self.size}")
        with self._space:
            while True:
                if self._broken is not None:
                    raise ArenaBroken(str(self._broken)) from self._broken
                head, size = self._head, self.size
                off = head % size
                pad = (size - off) if off + n > size else 0
                need = pad + n
                if (head + need) - self._tail <= size:
                    self._head = head + need
                    pos = head + pad
                    start = pos % size
                    return pos, self._buf[start:start + n]
                if not self._space.wait(timeout=timeout):
                    raise TimeoutError(
                        f"arena {self.name} full ({n} bytes) after "
                        f"{timeout}s; reader stalled?")

    def release_to(self, watermark: int) -> None:
        """Reader acknowledged everything below `watermark` (monotonic)."""
        with self._space:
            if watermark > self._tail:
                self._tail = watermark
                self._space.notify_all()

    # -- reader side -------------------------------------------------------

    def view(self, pos: int, nbytes: int) -> np.ndarray:
        """Zero-copy view of a slot the writer allocated (contiguous)."""
        start = pos % self.size
        return self._buf[start:start + nbytes]


# -- payload packing -------------------------------------------------------

def pack_payload(arena: Optional[ShmArena], value) -> PayloadDesc:
    """Copy one payload into the arena (bulk memcpy) or inline it.

    Accepts anything `repro.core.payload.as_u8` does.  This single copy
    into shared memory IS the caller-side capture: the peer snapshots
    out of the arena at submission, then the slot is released.
    """
    from .payload import as_u8  # local import: avoid cycle at module load

    u8 = as_u8(value)
    n = int(u8.nbytes)
    if arena is not None and n <= arena.size:
        pos, slot = arena.alloc(n)
        if n:
            slot[:] = u8
        return ("a", pos, n)
    return ("i", u8.tobytes())


def unpack_payload(arena: Optional[ShmArena], desc: PayloadDesc,
                   *, writable: bool = True):
    """Materialize a descriptor on the receiving side.

    Arena-backed descriptors come back as a *writable* numpy view by
    default: `InfiniStore._snapshot_value` copies writable buffers
    synchronously at submission, which is exactly the hand-off we want —
    the store owns a private copy, and the ring slot can be released the
    moment the call returns.  (A read-only view would be retained
    uncopied and later scribbled over by ring reuse.)
    """
    kind = desc[0]
    if kind == "a":
        _, pos, n = desc
        v = arena.view(pos, n)
        if not writable:
            v = v.copy()
            v.flags.writeable = False
        return v
    if kind == "i":
        return desc[1]
    raise ValueError(f"unknown payload descriptor {desc!r}")


def desc_watermark(descs: Sequence[PayloadDesc]) -> int:
    """Highest arena byte consumed by `descs` (0 when none are arena-backed)."""
    wm = 0
    for d in descs:
        if d[0] == "a":
            wm = max(wm, d[1] + d[2])
    return wm


def pack_items(arena: Optional[ShmArena],
               items: Sequence[Tuple[str, object]]) -> List[Tuple[str, PayloadDesc]]:
    return [(k, pack_payload(arena, v)) for k, v in items]


def unpack_items(arena: Optional[ShmArena],
                 items: Sequence[Tuple[str, PayloadDesc]]):
    return [(k, unpack_payload(arena, d)) for k, d in items]
