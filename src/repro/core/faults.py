"""Deterministic fault-injection plane + unified retry policy.

Failure is a first-class, replayable *input* here, not an afterthought:
a `FaultPlan` is a seeded collection of per-site `FaultPoint`s threaded
through every layer that can fail in a real deployment —

    site                    layer       injected failure
    ----------------------  ----------  --------------------------------
    cos.put / cos.get       COS         TransientCOSError, COSThrottle
                                        (SlowDown + injected latency)
    writeback.persist       writeback   writer-side COS faults
    sms.store / sms.load    SMS slab    slab reclaimed mid-store /
                                        mid-gather ("function death")
    spill.append/spill.sync journal     OSError on the ack path
    spill.io                journal     OSError on the async writer
    spill.torn_close        journal     torn frame in the unsynced tail
                                        on hard (SIGKILL) close
    shard.decision          2PC leader  death BEFORE the decision record
                                        is durable (presumed abort)
    shard.leader_death      2PC leader  death AFTER the commit decision
                                        is durable, before round 2
    shard.commit_submit     2PC leader  per-shard commit submission loss
    net.drop                transport   outbound frame silently lost
    net.delay               transport   injected latency before the send
    net.partition           transport   link blackholed both ways for
                                        `hb.partition_s` (frames lost,
                                        heartbeats fail, detector fires)
    net.dup                 transport   frame transmitted twice (worker
                                        rid-dedupe drops the replay)

Network sites key on ``op:<opname>:s<shard>`` for data frames and
``hb:s<shard>`` for heartbeat pings. A plan that targets data ops MUST
set ``match="op:..."`` — `fire()` only consumes a hit index when some
point's match passes, so unmatched heartbeat traffic never shifts a
data-op schedule and same-seed runs stay byte-identical.

Every decision is a pure function of ``(seed, site, hit_index)`` — no
shared RNG stream — so the set of triggering hits is identical run to
run even when threads race on *which* key draws a given hit index. The
plan records each trigger in ``plan.log``; two runs of the same seeded
schedule produce byte-identical logs, which is what the chaos soak
asserts. A plan is off by default (``faults=None`` everywhere) and every
instrumented site guards with a single ``is not None`` check, so the
disabled plane costs one attribute load per op (the soak benchmark gates
this at <= 2% of PUT-ack latency).

Retry policy table (``RetryPolicy.classify``):

    classification  errors                           behaviour
    --------------  -------------------------------  --------------------
    transient       TransientCOSError, Connection-   capped exponential
                    Error, TimeoutError, OSError     backoff + jitter,
                                                     retried to budget
    throttle        COSThrottleError (SlowDown)      backoff starts at
                                                     the cap (provider
                                                     asked us to slow)
    permanent       everything else (ValueError,     surfaced at once,
                    KeyError, corrupt payloads, ...) never retried

Per-op deadlines: ``RetryPolicy.run(..., deadline_s=)`` raises
``OpDeadlineExceeded`` when the budget is exhausted mid-retry; stores
surface it through the returned ``StoreFuture`` rather than swallowing
it into a miss.
"""
from __future__ import annotations

import errno
import hashlib
import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "TransientCOSError", "COSThrottleError", "InjectedFault",
    "InjectedCrash", "OpDeadlineExceeded", "FaultPoint", "FaultPlan",
    "RetryPolicy",
]


# ---------------------------------------------------------------------------
# error taxonomy
# ---------------------------------------------------------------------------

class TransientCOSError(ConnectionError):
    """A retryable cloud-object-store error (5xx / reset / timeout)."""


class COSThrottleError(TransientCOSError):
    """Provider throttling ("SlowDown"): retryable, but back off hard."""


class InjectedFault(Exception):
    """Marker mixin: the fault plane manufactured this failure."""


class InjectedCrash(InjectedFault):
    """An injected process/thread death (2PC leader kill)."""


class OpDeadlineExceeded(TimeoutError):
    """A per-op deadline expired while retrying transient failures."""


class _InjectedTransient(TransientCOSError, InjectedFault):
    pass


class _InjectedThrottle(COSThrottleError, InjectedFault):
    pass


class _InjectedOSError(OSError, InjectedFault):
    pass


# ---------------------------------------------------------------------------
# fault points + plan
# ---------------------------------------------------------------------------

#: actions `fire()` RAISES (the site sees an exception)
_RAISING = {
    "transient": lambda site, idx: _InjectedTransient(
        f"injected transient error at {site} (hit {idx})"),
    "throttle": lambda site, idx: _InjectedThrottle(
        f"injected SlowDown at {site} (hit {idx})"),
    "oserror": lambda site, idx: _InjectedOSError(
        errno.EIO, f"injected I/O error at {site} (hit {idx})"),
    "crash": lambda site, idx: InjectedCrash(
        f"injected crash at {site} (hit {idx})"),
}
#: actions `fire()` RETURNS (the site interprets them in-line)
_ADVISORY = ("reclaim", "torn", "drop", "dup", "partition", "delay")

#: every instrumented `fire()` site in the tree (the table in the
#: module docstring, one entry per row).  `istore-lint` cross-checks
#: fire()/FaultPoint call sites against this manifest so a typo'd
#: site cannot silently never fire.
FAULT_SITES = frozenset({
    "cos.put", "cos.get",
    "writeback.persist",
    "sms.store", "sms.load",
    "spill.append", "spill.sync", "spill.io", "spill.torn_close",
    "shard.decision", "shard.leader_death", "shard.commit_submit",
    "net.drop", "net.delay", "net.partition", "net.dup",
})


@dataclass
class FaultPoint:
    """One schedule of failures at one named site.

    Triggering is decided per *hit* (every call to ``FaultPlan.fire``
    for the site, after the optional key ``match`` filter): a hit fires
    when its 1-based index is in ``hits``, is a multiple of ``every``,
    exceeds ``after`` (k-ops-then-fail), or draws below ``prob`` from
    the seeded per-hit hash. ``times`` caps total fires. ``latency_s``
    is slept before the action (throttle/SlowDown latency injection).
    """
    site: str
    action: str = "transient"       # transient|throttle|oserror|crash|
                                    # reclaim|torn
    hits: Sequence[int] = ()        # explicit 1-based hit indices
    every: int = 0                  # fire every Nth hit
    after: int = -1                 # fire every hit with index > after
    prob: float = 0.0               # seeded per-hit probability
    times: Optional[int] = None     # cap on total fires (None = no cap)
    latency_s: float = 0.0          # injected delay before the action
    match: Optional[str] = None     # only keys containing this substring

    def __post_init__(self):
        if self.action not in _RAISING and self.action not in _ADVISORY:
            raise ValueError(f"unknown fault action: {self.action!r}")
        if (self.site.startswith("net.") or self.site.startswith("hb")) \
                and not self.match:
            raise ValueError(
                f"FaultPoint({self.site!r}) must set match= ("
                f"'op:...' or 'hb') — an unmatched point consumes hit "
                f"indices for heartbeat traffic too, breaking same-seed "
                f"log determinism")
        self.hits = frozenset(self.hits)
        self._fired = 0

    def _triggers(self, seed: int, idx: int) -> bool:
        if self.times is not None and self._fired >= self.times:
            return False
        if idx in self.hits:
            return True
        if self.every > 0 and idx % self.every == 0:
            return True
        if self.after >= 0 and idx > self.after:
            return True
        if self.prob > 0.0:
            h = hashlib.blake2b(
                f"{seed}|{self.site}|{self.action}|{idx}".encode(),
                digest_size=8).digest()
            u = int.from_bytes(h, "big") / 2.0 ** 64
            if u < self.prob:
                return True
        return False


class FaultPlan:
    """A seeded, deterministic schedule of failures across sites.

    Thread-safe; hit counters are per-site. ``fire(site, key)`` either
    returns None (no fault), returns an advisory action string the site
    interprets ("reclaim", "torn"), or raises the scheduled exception.
    ``log`` records every trigger as ``(site, hit_index, action)`` —
    the reproducibility artifact the chaos soak compares across runs.
    """

    def __init__(self, seed: int = 0,
                 points: Sequence[FaultPoint] = ()):
        self.seed = int(seed)
        self._sites: Dict[str, List[FaultPoint]] = {}
        self._hits: Dict[str, itertools.count] = {}
        self._lock = threading.Lock()
        self.log: List[Tuple[str, int, str]] = []
        self._sleep: Callable[[float], None] = time.sleep
        # optional ObsPlane (repro.obs): every trigger is mirrored into
        # the flight recorder ("fault.fire"), so post-crash forensics
        # show which injected faults preceded the failure. Set by the
        # owning store; NOT pickled (each process re-attaches its own).
        self.obs = None
        for p in points:
            self.add(p)

    def add(self, point: FaultPoint) -> "FaultPlan":
        with self._lock:
            self._sites.setdefault(point.site, []).append(point)
            self._hits.setdefault(point.site, itertools.count(1))
        return self

    def fire(self, site: str, key: str = "") -> Optional[str]:
        pts = self._sites.get(site)
        if not pts:                          # site unscheduled: no count
            return None
        with self._lock:
            hit = None
            armed = None
            for p in pts:
                if p.match is not None and p.match not in key:
                    continue
                if hit is None:              # one hit index per fire()
                    hit = next(self._hits[site])
                if p._triggers(self.seed, hit):
                    p._fired += 1
                    armed = p
                    break
            if armed is None:
                return None
            self.log.append((site, hit, armed.action))
            latency = armed.latency_s
            action = armed.action
        obs = self.obs
        if obs is not None:
            obs.event("fault.fire", at=site, hit=hit, action=action)
        if latency > 0.0:
            self._sleep(latency)
        maker = _RAISING.get(action)
        if maker is not None:
            raise maker(site, hit)
        return action            # advisory: reclaim|torn|drop|dup|...

    # -- pickling (multi-process shard host) --------------------------------
    #
    # A plan crosses into worker processes at spawn (StoreConfig.faults
    # inside the worker spec). Each process then owns an INDEPENDENT
    # copy: per-site hit counters restart from the serialized position
    # and advance with that process's own call sequence, so every
    # worker's schedule is deterministic in its own op stream (the only
    # coherent semantics without cross-process counter contention).
    # Leader sites (shard.decision / shard.leader_death /
    # shard.commit_submit) keep firing on the parent's copy.

    def __getstate__(self):
        with self._lock:
            state = dict(self.__dict__)
            # snapshot mutable containers under the lock: other threads
            # may append to `log` while pickle walks the object graph
            state["log"] = list(self.log)
            state["_sites"] = {s: list(ps)
                               for s, ps in self._sites.items()}
            state["_hits"] = dict(self._hits)  # count objects pickle
        del state["_lock"]
        state["_sleep"] = None                 # may be a test lambda
        state["obs"] = None                    # re-attached per process
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._lock = threading.Lock()
        self._sleep = time.sleep

    # -- introspection ------------------------------------------------------

    def fired(self, site: Optional[str] = None) -> int:
        with self._lock:
            if site is None:
                return len(self.log)
            return sum(1 for s, _, _ in self.log if s == site)

    def snapshot(self) -> dict:
        with self._lock:
            return {"seed": self.seed,
                    "fired": len(self.log),
                    "log": list(self.log)}


# ---------------------------------------------------------------------------
# unified retry policy
# ---------------------------------------------------------------------------

@dataclass
class RetryPolicy:
    """Capped exponential backoff + deterministic jitter, with the
    transient/throttle/permanent classification from the module
    docstring. One policy object replaces the three ad-hoc retry loops
    that used to live in writeback, `_cos_read_consistent`, and the
    recovery download path."""
    max_attempts: int = 8
    backoff_base_s: float = 0.01
    backoff_cap_s: float = 1.0
    jitter: float = 0.25            # +- fraction of the computed delay
    seed: int = 0

    TRANSIENT = "transient"
    THROTTLE = "throttle"
    PERMANENT = "permanent"

    def classify(self, exc: BaseException) -> str:
        if isinstance(exc, COSThrottleError):
            return self.THROTTLE
        if isinstance(exc, (TransientCOSError, ConnectionError,
                            TimeoutError, OSError)):
            return self.TRANSIENT
        return self.PERMANENT

    def retryable(self, exc: BaseException) -> bool:
        return self.classify(exc) != self.PERMANENT

    def delay(self, attempt: int, kind: str = TRANSIENT) -> float:
        """Backoff before retry number `attempt` (1-based). Throttle
        starts at the cap — the provider explicitly asked us to slow
        down, ramping up from the base just burns the budget."""
        if kind == self.THROTTLE:
            d = self.backoff_cap_s
        else:
            d = min(self.backoff_base_s * (2.0 ** (attempt - 1)),
                    self.backoff_cap_s)
        if self.jitter > 0.0 and d > 0.0:
            h = hashlib.blake2b(f"{self.seed}|{attempt}".encode(),
                                digest_size=8).digest()
            u = int.from_bytes(h, "big") / 2.0 ** 64   # [0, 1)
            d *= 1.0 + self.jitter * (2.0 * u - 1.0)
        return d

    def run(self, fn: Callable[[], object], *,
            deadline_s: Optional[float] = None,
            sleep: Callable[[float], None] = time.sleep,
            now: Callable[[], float] = time.monotonic,
            on_retry: Optional[Callable[[int, BaseException], None]]
            = None):
        """Call `fn` under this policy. Permanent errors surface at
        once; transient/throttle errors retry with backoff until the
        attempt budget or the per-op deadline runs out. Deadline
        exhaustion raises OpDeadlineExceeded chained to the last error;
        attempt exhaustion re-raises the last error itself."""
        deadline = None if deadline_s is None else now() + deadline_s
        attempt = 0
        while True:
            attempt += 1
            try:
                return fn()
            except BaseException as e:      # noqa: BLE001 — reclassified
                kind = self.classify(e)
                if kind == self.PERMANENT:
                    raise
                if attempt >= self.max_attempts:
                    raise
                d = self.delay(attempt, kind)
                if deadline is not None and now() + d > deadline:
                    raise OpDeadlineExceeded(
                        f"op deadline ({deadline_s:.3f}s) exceeded after "
                        f"{attempt} attempts: {e!r}") from e
                if on_retry is not None:
                    on_retry(attempt, e)
                if d > 0.0:
                    sleep(d)
