"""Payload protocol: zero-copy device/host payload handles (ROADMAP item).

The store's data plane historically forced every payload through host
`bytes`, which costs a serialize copy on PUT and a join copy on GET even
when the caller already holds a `numpy` or `jax.Array` buffer. This
module defines the small protocol the store actually needs from a
payload — a byte length and a flat `uint8` view — so the serving and
checkpoint layers can hand device-backed fragments straight to the
bit-sliced GF(256) kernel:

- `bytes` / `bytearray` / `memoryview`  -> `np.frombuffer` view (no copy)
- `np.ndarray` (any dtype)              -> `.view(np.uint8)` (no copy when
  contiguous; one copy otherwise)
- `jax.Array`                           -> one device-to-host transfer via
  `np.asarray` (the unavoidable DMA), then the ndarray path — never an
  intermediate `bytes` object.

Everything in the PUT path downstream of `as_u8` (fragment slicing,
erasure coding, slab stores, COS writeback) operates on `uint8` array
views of the original buffer.
"""
from __future__ import annotations

from typing import Union

import numpy as np

# What the store accepts as a value: anything bytes-like or array-like.
# (jax.Array satisfies __array__; core deliberately avoids importing jax.)
Payload = Union[bytes, bytearray, memoryview, np.ndarray]


def is_array_payload(p) -> bool:
    """True for ndarray-like payloads (numpy or device arrays)."""
    return not isinstance(p, (bytes, bytearray, memoryview)) \
        and hasattr(p, "__array__")


def payload_nbytes(p) -> int:
    if isinstance(p, (bytes, bytearray)):
        return len(p)
    if isinstance(p, memoryview):
        return p.nbytes
    if isinstance(p, np.ndarray):
        return p.nbytes
    if hasattr(p, "nbytes"):                    # jax.Array without transfer
        return int(p.nbytes)
    return len(p)


def as_u8(p) -> np.ndarray:
    """Flat uint8 view of the payload; copies only when unavoidable
    (non-contiguous arrays, device-to-host DMA for jax arrays)."""
    if isinstance(p, (bytes, bytearray, memoryview)):
        return np.frombuffer(p, np.uint8)
    arr = np.asarray(p)                          # host view / one DMA
    if not arr.flags.c_contiguous:
        arr = np.ascontiguousarray(arr)
    return arr.reshape(-1).view(np.uint8)


def needs_snapshot(p) -> bool:
    """True when the payload aliases caller-MUTABLE memory and the store
    must take a private copy at the ack boundary (the persistent buffer
    owns its data). bytes and device arrays (`jax.Array`) are immutable
    — their views are safe to hold; writable numpy buffers are not."""
    if isinstance(p, np.ndarray):
        return bool(p.flags.writeable)
    if isinstance(p, bytearray):
        return True
    if isinstance(p, memoryview):
        return not p.readonly
    return False


def to_bytes(p) -> bytes:
    """Materialize a payload as bytes (the legacy GET return type)."""
    if isinstance(p, bytes):
        return p
    if isinstance(p, (bytearray, memoryview)):
        return bytes(p)
    return as_u8(p).tobytes()
