"""Daemon-side versioning + persistent buffer (paper §5.3.2, Appendix A).

Objects are read-only once PUT returns; updates create new versions via
CAS on the metadata table. The persistent buffer intercepts the PUT data
path: a PUT acks after SMS insertion, while the COS write retries
asynchronously from the buffer; read-after-write GETs are served from the
buffer until release. The GET side runs the SCFS-style consistency-
increasing loop to mask COS eventual consistency.
"""
from __future__ import annotations

import enum
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.core.payload import payload_nbytes


class MetaStatus(enum.Enum):
    PENDING = 0
    DONE_OK = 1
    DONE_FAIL = 2


class Meta:
    def __init__(self, key: str, ver: int, prev_ver: int = 0):
        self.key = key
        self.ver = ver
        self.prev_ver = prev_ver
        self.status = MetaStatus.PENDING
        self._event = threading.Event()
        self.num_fragments = 1
        self.size = 0
        # True while this head sits prepared-but-uncommitted in a
        # cross-shard two-round batch. Readers and conflicting writers
        # must NOT block on such a head (the commit/abort that resolves
        # it is queued BEHIND them on the same single-threaded shard
        # daemon — waiting would stall the whole shard until timeout):
        # reads fall through to the previous version (uncommitted data
        # is invisible), writers conflict immediately. Cleared by
        # `done()` on commit and abort alike.
        self.prepared = False

    # Fig. 24 primitives ----------------------------------------------------

    def is_done(self) -> bool:
        return self.status != MetaStatus.PENDING

    def is_done_ok(self) -> bool:
        return self.status == MetaStatus.DONE_OK

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._event.wait(timeout)

    def done(self, ok: bool) -> bool:
        self.status = MetaStatus.DONE_OK if ok else MetaStatus.DONE_FAIL
        self.prepared = False
        self._event.set()
        return ok

    def revise(self, ver: int) -> None:
        self.prev_ver = ver - 1
        self.ver = ver


class MetadataTable:
    """In-memory metadata table with CAS; persisted to local disk by the
    daemon for fault tolerance (§5.2) — here: snapshot()/restore()."""

    def __init__(self):
        self._t: Dict[str, Meta] = {}
        self._lock = threading.RLock()

    def prepare(self, key: str, ver: int = 1) -> Meta:
        return Meta(key, ver)

    def load(self, key: str) -> Optional[Meta]:
        with self._lock:
            return self._t.get(key)

    def cas(self, key: str, candidate: Meta) -> Tuple[Optional[Meta], bool]:
        """Insert candidate as the head metadata for key unless a PENDING
        or newer entry exists. Returns (current, ok)."""
        return self.cas_many([(key, candidate)])[0]

    def cas_many(self, items: "list[Tuple[str, Meta]]"
                 ) -> "list[Tuple[Optional[Meta], bool]]":
        """Multi-key CAS: commit a batch of candidates in ONE leader-
        sequenced metadata round (one lock acquisition) instead of one
        round per key. Keys succeed/fail independently — a PENDING or
        newer head fails only that key. Checkpoint saves are the main
        beneficiary: B leaf shards -> 1 metadata round."""
        out: "list[Tuple[Optional[Meta], bool]]" = []
        with self._lock:
            for key, candidate in items:
                cur = self._t.get(key)
                if cur is None or (cur.is_done()
                                   and candidate.ver == cur.ver + 1):
                    if cur is not None:
                        candidate.prev_ver = cur.ver
                    self._t[key] = candidate
                    out.append((candidate, True))
                else:
                    out.append((cur, False))
        return out

    def store(self, versioned_key: str, meta: Meta) -> None:
        with self._lock:
            self._t[versioned_key] = meta

    def snapshot(self) -> Dict[str, Tuple[int, int, int]]:
        with self._lock:
            return {k: (m.ver, m.prev_ver, m.status.value)
                    for k, m in self._t.items()}

    def restore(self, snap: Dict[str, Tuple[int, int, int]]) -> None:
        with self._lock:
            for k, (ver, prev, status) in snap.items():
                m = Meta(k, ver, prev)
                m.status = MetaStatus(status)
                if m.is_done():
                    m._event.set()
                self._t[k] = m


@dataclass
class _BufEntry:
    data: object                  # bytes or flat uint8 ndarray (zero-copy)
    refs: int = 1


class PersistentBuffer:
    """Daemon-local stream buffer keyed by `key|ver[/frag]` (§5.3.2).

    Entries are refcounted so the async writeback path can drain them
    incrementally: a PUT creates the entry with one ref per derived COS
    write, each completed (or abandoned) write releases one ref, and the
    entry — which serves read-after-write GETs and the durability
    fallback meanwhile — is freed when the last ref drops. Payloads are
    stored as handed in (bytes or uint8 views), never copied."""

    def __init__(self):
        self._buf: Dict[str, _BufEntry] = {}
        self._lock = threading.RLock()
        self.peak_bytes = 0
        self.hits = 0

    def create(self, key: str, data, refs: int = 1) -> str:
        with self._lock:
            self._buf[key] = _BufEntry(data, refs=max(refs, 1))
            self.peak_bytes = max(
                self.peak_bytes,
                sum(payload_nbytes(e.data) for e in self._buf.values()))
            return key

    def load(self, key: str):
        with self._lock:
            e = self._buf.get(key)
            if e is not None:
                self.hits += 1
                return e.data
            return None

    def retain(self, key: str) -> None:
        """Add a ref (one per in-flight writeback of derived data)."""
        with self._lock:
            e = self._buf.get(key)
            if e is not None:
                e.refs += 1

    def release(self, key: str) -> bool:
        """Drop one ref; the entry is freed when the last ref drops.
        Returns True exactly when this call freed the entry (the spill
        journal truncates the fragment record on that edge)."""
        with self._lock:
            e = self._buf.get(key)
            if e is None:
                return False
            e.refs -= 1
            if e.refs <= 0:
                self._buf.pop(key, None)
                return True
            return False

    def release_all(self, key: str) -> None:
        """Force-drop the entry regardless of refcount (failure paths)."""
        with self._lock:
            self._buf.pop(key, None)

    @property
    def size_bytes(self) -> int:
        with self._lock:
            return sum(payload_nbytes(e.data) for e in self._buf.values())
