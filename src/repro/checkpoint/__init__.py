from repro.checkpoint.checkpointer import (Checkpointer,  # noqa: F401
                                           CheckpointConfig)
