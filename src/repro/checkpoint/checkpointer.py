"""InfiniStore-backed distributed checkpointing (DESIGN.md §2.2).

Train state leaves ride the store's zero-copy Payload path: each leaf
(device `jax.Array` or host numpy) becomes ONE host transfer + a flat
uint8 view that is fragmented, RS-erasure-coded, and PUT through the
InfiniStore data path — no intermediate `bytes` serialization. The SMS
tier (host-RAM slabs of DP peers) gives fast restore, the COS tier
(disk) gives durability, insertion logs give term-stamped failure
detection, and parallel recovery restores a lost host's chunks without a
full COS read.

Persistent-buffer semantics (§5.3.2): `save()` returns once SMS accepted
every shard — COS writes drain from the background writeback queue, and
shard batches ride `put_many_async` (one multi-key CAS round per batch)
so the next batch's host transfer overlaps the previous batch's encode.
An instance failure between save() and writeback completion loses
nothing: restore reads unpersisted chunks from the pending map.

Elastic restart: leaves are stored whole (per-leaf chunks), so restoring
onto a different DP width just re-shards at jit boundary — exercised by
tests/test_checkpoint.py.
"""
from __future__ import annotations

import json
import threading
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.payload import as_u8
from repro.core.store import StoreFrontend

PyTree = Any


@dataclass
class CheckpointConfig:
    prefix: str = "ckpt"
    keep: int = 3                     # retained checkpoints
    leaf_shard_bytes: int = 64 * 1024 * 1024   # split huge leaves
    max_inflight_batches: int = 2     # pipelined async PUT batches


def _leaf_paths(tree: PyTree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name, leaf))
    return out


def _restore_dtype(name: str):
    if name == "bfloat16":
        return jax.numpy.bfloat16
    return np.dtype(name)


class Checkpointer:
    """Works over any `StoreFrontend` — the singleton `InfiniStore` or
    the keyspace-partitioned `ShardedStore`. Under a sharded store the
    ordered `.../sN` shard keys scatter by the router, so save batches
    fan out across every shard daemon (one multi-key CAS round per
    shard per sub-batch, leader-sequenced when a batch spans shards)
    and restores gather in parallel from all of them."""

    def __init__(self, store: StoreFrontend,
                 cfg: CheckpointConfig = CheckpointConfig()):
        self.store = store
        self.cfg = cfg
        self._saved_steps: List[int] = []
        self._lock = threading.Lock()

    # ---- save -------------------------------------------------------------

    def _manifest_key(self, step: int) -> str:
        return f"{self.cfg.prefix}/manifest/{step:08d}"

    def save(self, step: int, state: PyTree) -> None:
        leaves = _leaf_paths(state)
        manifest = {"step": step, "leaves": []}
        # shards ride pipelined async batched PUTs, flushed in bounded
        # sub-batches so peak host memory stays O(limit) (encode_many
        # materializes ~(k+p)/k x the sub-batch bytes) while keeping the
        # per-function invoke/log amortization within each sub-batch; at
        # most max_inflight_batches are outstanding at once
        limit = max(4 * self.cfg.leaf_shard_bytes, 64 * 1024 * 1024)
        sub, sub_bytes = [], 0
        inflight: List[Any] = []
        for name, leaf in leaves:
            # ONE device-to-host transfer per leaf; everything downstream
            # operates on this flat uint8 view (no bytes serialization)
            u8 = as_u8(leaf)
            nshards = max(1, -(-u8.size // self.cfg.leaf_shard_bytes))
            for si in range(nshards):
                lo = si * self.cfg.leaf_shard_bytes
                hi = min(u8.size, lo + self.cfg.leaf_shard_bytes)
                sub.append((self._leaf_key(step, name, si), u8[lo:hi]))
                sub_bytes += hi - lo
                if sub_bytes >= limit:
                    inflight.append(self.store.put_many_async(sub))
                    sub, sub_bytes = [], 0
                    while len(inflight) >= self.cfg.max_inflight_batches:
                        inflight.pop(0).result()
            # dtype/shape come from the handle — no second host transfer
            dtype = getattr(leaf, "dtype", None)
            shape = getattr(leaf, "shape", None)
            if dtype is None or shape is None:    # python scalar leaf
                arr = np.asarray(leaf)
                dtype, shape = arr.dtype, arr.shape
            manifest["leaves"].append(
                {"name": name, "dtype": str(dtype),
                 "shape": list(shape), "nshards": nshards,
                 "nbytes": int(u8.size)})
        if sub:
            inflight.append(self.store.put_many_async(sub))
        for fut in inflight:
            fut.result()                         # SMS-accept barrier
        self.store.put(self._manifest_key(step),
                       json.dumps(manifest).encode())
        with self._lock:
            self._saved_steps.append(step)
            self._gc_old()

    def _leaf_key(self, step: int, name: str, shard: int) -> str:
        return f"{self.cfg.prefix}/{step:08d}/{name}/s{shard}"

    def _gc_old(self) -> None:
        while len(self._saved_steps) > self.cfg.keep:
            self._saved_steps.pop(0)
            # slabs age out via the GC window; COS retains durably

    # ---- restore -----------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        steps = []
        # cos_keys includes acked-but-not-yet-persisted manifests (the
        # pending writeback map), so a fresh save is always discoverable
        # chunk keys look like "chunk/<prefix>/manifest/<step>|<ver>/f0#N"
        # — the step sits in the second-to-last path component
        for key in self.store.cos_keys(
                f"chunk/{self.cfg.prefix}/manifest/"):
            try:
                steps.append(int(key.split("/")[-2].split("|")[0]))
            except (ValueError, IndexError):
                pass
        if self._saved_steps:
            steps.extend(self._saved_steps)
        return max(steps) if steps else None

    def restore(self, step: int, like: Optional[PyTree] = None) -> PyTree:
        mb = self.store.get(self._manifest_key(step))
        if mb is None:
            raise FileNotFoundError(f"no checkpoint manifest for {step}")
        manifest = json.loads(bytes(mb).decode())
        shard_keys = [self._leaf_key(step, entry["name"], si)
                      for entry in manifest["leaves"]
                      for si in range(entry["nshards"])]
        # batched array GETs in bounded sub-batches, mirroring save():
        # one unbounded get would hold ~3-4x the checkpoint in host RAM.
        # get_many_arrays returns flat uint8 views — leaves rebuild via
        # dtype/shape views, never through an intermediate bytes object.
        # Batches ride async futures with at most max_inflight_batches
        # outstanding, so batch i+1 queues on the client daemon while
        # batch i decodes — and the ordered .../sN shard keys let the
        # store's sequential-scan prefetcher warm the next shards' chunks
        # from COS during that decode (the degraded-restore fast path).
        limit = max(4 * self.cfg.leaf_shard_bytes, 64 * 1024 * 1024)
        per_batch = max(1, limit // self.cfg.leaf_shard_bytes)
        shards: Dict[str, Optional[np.ndarray]] = {}
        inflight: List[Any] = []
        for i in range(0, len(shard_keys), per_batch):
            inflight.append(self.store.get_many_arrays_async(
                shard_keys[i:i + per_batch]))
            while len(inflight) >= self.cfg.max_inflight_batches:
                shards.update(inflight.pop(0).result())
        for fut in inflight:
            shards.update(fut.result())
        leaves: Dict[str, np.ndarray] = {}
        for entry in manifest["leaves"]:
            parts = []
            for si in range(entry["nshards"]):
                a = shards.get(self._leaf_key(step, entry["name"], si))
                if a is None:
                    raise IOError(
                        f"checkpoint shard lost: {entry['name']}/s{si}")
                parts.append(a)
            u8 = parts[0] if len(parts) == 1 else np.concatenate(parts)
            arr = u8.view(_restore_dtype(entry["dtype"]))
            leaves[entry["name"]] = arr.reshape(entry["shape"])
        if like is None:
            return leaves
        named = _leaf_paths(like)
        flat = [leaves[name] for name, _ in named]
        treedef = jax.tree_util.tree_structure(like)
        return jax.tree_util.tree_unflatten(treedef, flat)
