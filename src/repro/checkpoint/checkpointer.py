"""InfiniStore-backed distributed checkpointing (DESIGN.md §2.2).

Train state leaves are serialized, RS-erasure-coded, and PUT through the
InfiniStore data path: the SMS tier (host-RAM slabs of DP peers) gives
fast restore, the COS tier (disk) gives durability, insertion logs give
term-stamped failure detection, and parallel recovery restores a lost
host's chunks without a full COS read. The paper's persistent buffer
semantics = save() returns once SMS accepted; COS writes complete
asynchronously.

Elastic restart: leaves are stored whole (per-leaf chunks), so restoring
onto a different DP width just re-shards at jit boundary — exercised by
tests/test_checkpoint.py.
"""
from __future__ import annotations

import io
import json
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.store import InfiniStore, StoreConfig

PyTree = Any


@dataclass
class CheckpointConfig:
    prefix: str = "ckpt"
    keep: int = 3                     # retained checkpoints
    leaf_shard_bytes: int = 64 * 1024 * 1024   # split huge leaves


def _leaf_paths(tree: PyTree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name, leaf))
    return out


def _pack(arr: np.ndarray) -> bytes:
    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    return buf.getvalue()


def _unpack(b: bytes) -> np.ndarray:
    return np.load(io.BytesIO(b), allow_pickle=False)


class Checkpointer:
    def __init__(self, store: InfiniStore,
                 cfg: CheckpointConfig = CheckpointConfig()):
        self.store = store
        self.cfg = cfg
        self._saved_steps: List[int] = []
        self._lock = threading.Lock()

    # ---- save -------------------------------------------------------------

    def _manifest_key(self, step: int) -> str:
        return f"{self.cfg.prefix}/manifest/{step:08d}"

    def save(self, step: int, state: PyTree) -> None:
        leaves = _leaf_paths(state)
        manifest = {"step": step, "leaves": []}
        # shards ride batched PUTs, flushed in bounded sub-batches so
        # peak host memory stays O(limit) (encode_many materializes
        # ~(k+p)/k x the sub-batch bytes) while keeping the per-function
        # invoke/log amortization within each sub-batch
        limit = max(4 * self.cfg.leaf_shard_bytes, 64 * 1024 * 1024)
        sub, sub_bytes = [], 0
        for name, leaf in leaves:
            arr = np.asarray(leaf)
            if arr.dtype == jax.numpy.bfloat16:
                arr16 = arr.view(np.uint16)
                payload_dtype = "bfloat16"
                arr_to_store = arr16
            else:
                payload_dtype = str(arr.dtype)
                arr_to_store = arr
            data = _pack(arr_to_store)
            nshards = max(1, -(-len(data) // self.cfg.leaf_shard_bytes))
            for si in range(nshards):
                lo = si * self.cfg.leaf_shard_bytes
                hi = min(len(data), lo + self.cfg.leaf_shard_bytes)
                sub.append((self._leaf_key(step, name, si), data[lo:hi]))
                sub_bytes += hi - lo
                if sub_bytes >= limit:
                    self.store.put_many(sub)
                    sub, sub_bytes = [], 0
            manifest["leaves"].append(
                {"name": name, "dtype": payload_dtype,
                 "shape": list(arr.shape), "nshards": nshards,
                 "nbytes": len(data)})
        if sub:
            self.store.put_many(sub)
        self.store.put(self._manifest_key(step),
                       json.dumps(manifest).encode())
        with self._lock:
            self._saved_steps.append(step)
            self._gc_old()

    def _leaf_key(self, step: int, name: str, shard: int) -> str:
        return f"{self.cfg.prefix}/{step:08d}/{name}/s{shard}"

    def _gc_old(self) -> None:
        while len(self._saved_steps) > self.cfg.keep:
            self._saved_steps.pop(0)
            # slabs age out via the GC window; COS retains durably

    # ---- restore -----------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        steps = []
        for key in self.store.cos.list_keys(f"chunk/{self.cfg.prefix}/manifest/"):
            try:
                steps.append(int(key.split("/")[-1].split("|")[0]))
            except ValueError:
                pass
        if self._saved_steps:
            steps.extend(self._saved_steps)
        return max(steps) if steps else None

    def restore(self, step: int, like: Optional[PyTree] = None) -> PyTree:
        mb = self.store.get(self._manifest_key(step))
        if mb is None:
            raise FileNotFoundError(f"no checkpoint manifest for {step}")
        manifest = json.loads(mb.decode())
        shard_keys = [self._leaf_key(step, entry["name"], si)
                      for entry in manifest["leaves"]
                      for si in range(entry["nshards"])]
        # batched decode in bounded sub-batches, mirroring save(): one
        # unbounded get_many would hold ~3-4x the checkpoint in host RAM
        limit = max(4 * self.cfg.leaf_shard_bytes, 64 * 1024 * 1024)
        per_batch = max(1, limit // self.cfg.leaf_shard_bytes)
        shards: Dict[str, Optional[bytes]] = {}
        for i in range(0, len(shard_keys), per_batch):
            shards.update(self.store.get_many(shard_keys[i:i + per_batch]))
        leaves: Dict[str, np.ndarray] = {}
        for entry in manifest["leaves"]:
            parts = []
            for si in range(entry["nshards"]):
                b = shards.get(self._leaf_key(step, entry["name"], si))
                if b is None:
                    raise IOError(
                        f"checkpoint shard lost: {entry['name']}/s{si}")
                parts.append(b)
            arr = _unpack(b"".join(parts))
            if entry["dtype"] == "bfloat16":
                arr = arr.view(jax.numpy.bfloat16)
            leaves[entry["name"]] = arr.reshape(entry["shape"])
        if like is None:
            return leaves
        named = _leaf_paths(like)
        flat = [leaves[name] for name, _ in named]
        treedef = jax.tree_util.tree_structure(like)
        return jax.tree_util.tree_unflatten(treedef, flat)
