"""repro.obs — unified observability plane for the InfiniStore repro.

Three legs behind one off-by-default handle (`ObsPlane`):

- **Tracing** (`obs.trace`): per-op spans with ambient thread-local
  context, propagated across executor hops and across the process
  transports so worker-side spans stitch into the frontend's trace.
- **Metrics** (`obs.metrics`): lock-free log-spaced latency histograms
  with p50/p99/p999 extraction, bucket-mergeable across shards and
  worker processes, exported as Prometheus text / JSON.
- **Flight recorder** (`obs.recorder`): bounded structured-event ring
  mirrored to a small mmap'd file per crash domain, so a SIGKILL'd
  worker's last events (and spans) are recoverable forensics.

Site names are governed by `obs.sites.METRIC_SITES`; the
`metric_site` lint rule (`repro.devtools`) enforces that every
instrumentation call uses a registered literal. See
`docs/observability.md` for the registry, span taxonomy, and event
schema.
"""
from repro.obs.metrics import (LatencyHistogram, NBUCKETS,  # noqa: F401
                               bucket_of, merge_counts, parse_prometheus,
                               quantile_us, summarize, to_prometheus)
from repro.obs.plane import (ObsPlane,  # noqa: F401
                             merge_metric_snapshots)
from repro.obs.recorder import FlightRecorder  # noqa: F401
from repro.obs.sites import (EVENT_SITES, HISTOGRAM_SITES,  # noqa: F401
                             METRIC_SITES, SPAN_SITES)
from repro.obs.trace import NOOP_CM, Span, Tracer, current, use  # noqa: F401
