"""The observability site registry — the single source of truth for
every metric, span, and flight-recorder event name in the tree.

Instrumentation sites must pass one of these names as a string LITERAL
(`obs.record("put.ack_us", ...)`, `obs.span("daemon.put_many")`,
`obs.event("wb.degraded_enter", ...)`): the `metric_site` lint rule
(`repro.devtools.rules`) cross-checks every call site against
`METRIC_SITES` exactly the way `fault_site` polices
`faults.FAULT_SITES`, so a typo'd or unregistered name is a CI failure,
not a silently-empty time series. The Prometheus-dump CI gate
(`scripts/check_metrics_dump.py`) closes the loop from the other side:
every `HISTOGRAM_SITES` name must appear in the exported dump.

Naming convention: `<stage>.<what>`, histograms suffixed with their
unit (`_us` = microseconds).
"""
from __future__ import annotations

# Latency histograms (log-spaced fixed buckets, p50/p99/p999).
HISTOGRAM_SITES = frozenset({
    "put.ack_us",                  # daemon PUT path: submit -> durable ack
    "put.journal_sync_us",         # spill-journal group-commit at the ack point
    "get.sms_sweep_us",            # grouped SMS sweep stage of a GET batch
    "get.cos_fallback_us",         # one demand COS chunk-fetch task
    "get.decode_batch_us",         # one ready-order decode_many batch
    "wb.persist_us",               # one background COS writeback PUT
    "rpc.roundtrip_us",            # parent->worker RPC, send to reply
    "transport.heartbeat_age_us",  # pong age sampled at each heartbeat tick
})

# Trace spans (per-op, stitched across threads and processes).
SPAN_SITES = frozenset({
    "client.put_many",             # frontend submission (root)
    "client.get_many",             # frontend submission (root)
    "leader.2pc",                  # cross-shard two-round commit, leader side
    "daemon.put_many",             # client-daemon PUT execution
    "daemon.get_many",             # client-daemon GET execution
    "daemon.2pc_prepare",          # round 1 on a participant shard
    "daemon.2pc_commit",           # round 2 on a participant shard
    "ec.encode",                   # RS encode_many of a batch's fragments
    "get.cos_fallback",            # demand COS chunk fetch (I/O executor)
    "get.decode",                  # ready-order decode_many batch
    "wb.persist",                  # background COS write of one chunk
    "journal.append",              # one spill-journal record build+write
    "journal.sync",                # spill-journal durability barrier
})

# Flight-recorder events (state transitions; mirrored to the mmap ring).
EVENT_SITES = frozenset({
    "store.open",                  # a store/worker came up (forensics anchor)
    "wb.degraded_enter",           # writeback flipped into DEGRADED_WRITEBACK
    "wb.degraded_heal",            # COS healed, queue draining again
    "transport.suspect",           # heartbeat aged past suspect_after_s
    "transport.down",              # worker declared DOWN
    "transport.reconnect",         # epoch-fenced reconnect succeeded
    "epoch.bump",                  # worker accepted a new connection epoch
    "2pc.indoubt_resolved",        # an in-doubt ticket rolled forward/back
    "fault.fire",                  # deterministic fault plane fired an action
    "shard.restart",               # parent rebuilt a (crashed) shard
})

# The one manifest the lint rule reads (mirrors faults.FAULT_SITES).
# Keep this literal — the AST scan collects the string constants.
METRIC_SITES = frozenset({
    "put.ack_us",
    "put.journal_sync_us",
    "get.sms_sweep_us",
    "get.cos_fallback_us",
    "get.decode_batch_us",
    "wb.persist_us",
    "rpc.roundtrip_us",
    "transport.heartbeat_age_us",
    "client.put_many",
    "client.get_many",
    "leader.2pc",
    "daemon.put_many",
    "daemon.get_many",
    "daemon.2pc_prepare",
    "daemon.2pc_commit",
    "ec.encode",
    "get.cos_fallback",
    "get.decode",
    "wb.persist",
    "journal.append",
    "journal.sync",
    "store.open",
    "wb.degraded_enter",
    "wb.degraded_heal",
    "transport.suspect",
    "transport.down",
    "transport.reconnect",
    "epoch.bump",
    "2pc.indoubt_resolved",
    "fault.fire",
    "shard.restart",
})

# the big literal and the per-kind registries must agree — import-time
# check so a name added to one place cannot silently miss the other
assert METRIC_SITES == HISTOGRAM_SITES | SPAN_SITES | EVENT_SITES, \
    "METRIC_SITES out of sync with HISTOGRAM/SPAN/EVENT_SITES"
assert not (HISTOGRAM_SITES & SPAN_SITES & EVENT_SITES)
