"""Lock-free log-spaced latency histograms + Prometheus/JSON export.

`LatencyHistogram` is a fixed-bucket histogram over microseconds with
log-spaced bounds (factor 2^(1/4) ≈ 19% per bucket, 1 µs .. ~12 s, one
overflow bucket). Every bucket cell is an `itertools.count` — a record
is ONE `next()` call, atomic under the GIL, so any number of writer
threads (client daemon, writeback writer, GET I/O workers, heartbeat
loops) increment concurrently without a lock and without lost updates:
the same multi-writer discipline as `store.AtomicCounter`. Reads
snapshot each cell via `__reduce__` (also one C call).

Snapshots are plain count lists, so they are *mergeable*: per-shard and
per-worker-process histograms sum bucket-wise into the store-wide view
(`merge_counts`), and percentiles are extracted from any count list
(`summarize` → p50/p99/p999 at bucket resolution, ≤ ~10% relative
error — honest for SLO reporting, cheap enough for the hot path).

`to_prometheus` renders a `snapshot_metrics()` dict as Prometheus text
(summary-style quantile series + plain counters); `scripts/
check_metrics_dump.py` gates that the dump parses and covers every
`HISTOGRAM_SITES` name.
"""
from __future__ import annotations

import itertools
import json
import math
from bisect import bisect_right
from typing import Dict, Iterable, List, Optional, Sequence

NBUCKETS = 96
# bucket i holds values in (BOUNDS_US[i-1], BOUNDS_US[i]]; the last
# bucket is the overflow bucket
BOUNDS_US = tuple(2.0 ** (i / 4.0) for i in range(NBUCKETS - 1))


def bucket_of(us: float) -> int:
    if us <= 1.0:
        return 0
    return bisect_right(BOUNDS_US, us)


def bucket_upper_us(i: int) -> float:
    if i >= NBUCKETS - 1:
        return math.inf
    return BOUNDS_US[i]


def _bucket_rep_us(i: int) -> float:
    """Representative value reported for bucket i: the geometric middle
    of its bounds (the upper bound for the edge buckets)."""
    if i == 0:
        return 1.0
    if i >= NBUCKETS - 1:
        return BOUNDS_US[-1]
    return math.sqrt(BOUNDS_US[i - 1] * BOUNDS_US[i])


class LatencyHistogram:
    """Fixed-bucket log-spaced histogram; see the module docstring for
    the concurrency model."""
    __slots__ = ("_cells",)

    def __init__(self, counts: Optional[Sequence[int]] = None):
        if counts is None:
            self._cells = [itertools.count(0) for _ in range(NBUCKETS)]
        else:
            self._cells = [itertools.count(int(c)) for c in counts]

    def record(self, us: float) -> None:
        """Lock-free: one GIL-atomic `next()` on the bucket cell."""
        next(self._cells[bucket_of(us)])

    def snapshot(self) -> List[int]:
        return [c.__reduce__()[1][0] for c in self._cells]

    def count(self) -> int:
        return sum(self.snapshot())


def merge_counts(counts_list: Iterable[Sequence[int]]) -> List[int]:
    """Bucket-wise sum of histogram snapshots (shards, workers)."""
    merged = [0] * NBUCKETS
    for counts in counts_list:
        for i, c in enumerate(counts):
            merged[i] += c
    return merged


def quantile_us(counts: Sequence[int], q: float) -> float:
    total = sum(counts)
    if total == 0:
        return 0.0
    rank = max(1, math.ceil(q * total))
    seen = 0
    for i, c in enumerate(counts):
        seen += c
        if seen >= rank:
            return _bucket_rep_us(i)
    return _bucket_rep_us(NBUCKETS - 1)


def summarize(counts: Sequence[int]) -> Dict[str, float]:
    """count + p50/p99/p999 (µs) from one bucket-count snapshot."""
    total = sum(counts)
    return {"count": total,
            "p50_us": round(quantile_us(counts, 0.50), 1),
            "p99_us": round(quantile_us(counts, 0.99), 1),
            "p999_us": round(quantile_us(counts, 0.999), 1)}


# ---------------------------------------------------------------------------
# export
# ---------------------------------------------------------------------------

def _prom_name(site: str) -> str:
    return "istore_" + site.replace(".", "_").replace("-", "_")


def to_prometheus(snapshot: Dict) -> str:
    """Render a `snapshot_metrics()` dict as Prometheus text: one
    summary per histogram site (quantile series + `_count`), one
    counter per entry of the flat counter sections (`counters`, the
    transport totals)."""
    lines: List[str] = []
    for site in sorted(snapshot.get("histograms", {})):
        h = snapshot["histograms"][site]
        name = _prom_name(site)
        lines.append(f"# TYPE {name} summary")
        for q, key in (("0.5", "p50_us"), ("0.99", "p99_us"),
                       ("0.999", "p999_us")):
            lines.append(f'{name}{{quantile="{q}"}} {h[key]}')
        lines.append(f"{name}_count {h['count']}")
    counters = dict(snapshot.get("counters", {}))
    transport = snapshot.get("transport") or {}
    for k, v in (transport.get("totals") or {}).items():
        counters[f"transport.{k}"] = v
    for cname in sorted(counters):
        name = _prom_name(cname)
        lines.append(f"# TYPE {name} counter")
        lines.append(f"{name} {counters[cname]}")
    lines.append(f"istore_obs_enabled {int(bool(snapshot.get('enabled')))}")
    return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> Dict[str, Dict]:
    """Minimal parser for the dump format above (the CI gate): returns
    {metric_name: {labels-frozen-str: value}}. Raises ValueError on any
    malformed sample line."""
    out: Dict[str, Dict] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        name_part, _, value = line.rpartition(" ")
        if not name_part:
            raise ValueError(f"malformed sample line: {line!r}")
        float(value)                      # must be numeric
        if "{" in name_part:
            name, _, rest = name_part.partition("{")
            if not rest.endswith("}"):
                raise ValueError(f"malformed labels: {line!r}")
            labels = rest[:-1]
        else:
            name, labels = name_part, ""
        out.setdefault(name, {})[labels] = float(value)
    return out


def dump_json(snapshot: Dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(snapshot, f, indent=2, default=str)
        f.write("\n")
