"""Crash-surviving flight recorder: bounded event ring + mmap mirror.

The in-memory half is a bounded ring of structured events (state
transitions: DEGRADED_WRITEBACK enter/heal, SUSPECT/DOWN, epoch bumps,
in-doubt resolutions, fault-plane fires) — `deque(maxlen)` appends are
GIL-atomic, so recording is lock-free like the histogram cells.

The durable half is a small fixed-size mmap'd file (`flight.bin` in the
owning store's spill directory — `<spill_dir>/shard-<i>/` for a worker
process): every event (and every finished span, so a trace survives its
process) is also written into a slot ring in the file. mmap stores land
in the OS page cache, which survives a SIGKILL of the process — exactly
the crash domain the recorder exists for — so `restart_shard()` can
read the dead worker's last pre-kill events back out and surface them
as forensics. (Machine-crash durability is explicitly NOT the contract;
that is the spill journal's job.)

File format, all little-endian:

    header  magic u32 0x464C5431 ("FLT1"), slot_size u16, nslots u16
    slot    length u16, then `length` bytes of compact JSON

Slots are assigned round-robin from an atomic counter, so concurrent
writers touch distinct slots; the reader orders records by the embedded
`seq` and skips anything that does not parse (a torn slot from a crash
mid-store loses that one record only).
"""
from __future__ import annotations

import itertools
import json
import mmap
import os
import struct
import time
from collections import deque
from typing import Dict, List, Optional

_MAGIC = 0x464C5431                       # "FLT1"
_HDR = struct.Struct("<IHH")
_LEN = struct.Struct("<H")
DEFAULT_SLOTS = 256
DEFAULT_SLOT_SIZE = 256


class FlightRecorder:
    """Bounded structured-event ring with an optional mmap mirror."""

    def __init__(self, capacity: int = DEFAULT_SLOTS):
        self._ring: deque = deque(maxlen=capacity)
        self._seq = itertools.count(1)
        self._slot = itertools.count(0)
        self._nslots = capacity
        self._slot_size = DEFAULT_SLOT_SIZE
        # guards the mmap handle lifecycle (bind/close vs concurrent
        # writers); a strict leaf lock — nothing is acquired under it.
        # (Constructor-time import: repro.core layers import repro.obs,
        # so a module-level core import here would be circular.)
        from repro.core.locks import make_lock
        self._lock = make_lock("recorder.FlightRecorder._lock")
        self._mmap: Optional[mmap.mmap] = None
        self._file = None
        self._path: Optional[str] = None

    # ---- recording --------------------------------------------------------

    def event(self, site: str, **fields) -> Dict:
        """Record one structured event; returns the record dict."""
        rec = {"seq": next(self._seq), "ts": round(time.time(), 6),
               "kind": site}
        rec.update(fields)
        self._ring.append(rec)
        self._write_file(rec)
        return rec

    def mirror(self, rec: Dict) -> None:
        """Write a record to the mmap file only (no ring entry) — used
        for finished spans, which live in the tracer's own ring."""
        rec = dict(rec)
        rec.setdefault("seq", next(self._seq))
        self._write_file(rec)

    def snapshot(self) -> List[Dict]:
        return [dict(r) for r in list(self._ring)]

    # ---- mmap mirror ------------------------------------------------------

    @property
    def path(self) -> Optional[str]:
        return self._path

    def bind(self, path: str) -> bool:
        """Attach the mmap mirror at `path` (truncates any previous
        incarnation — the caller reads forensics BEFORE rebinding).
        First bind wins; returns whether this call bound it."""
        with self._lock:
            if self._mmap is not None:
                return False
            size = _HDR.size + self._nslots * self._slot_size
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            f = open(path, "w+b")
            f.truncate(size)
            m = mmap.mmap(f.fileno(), size)
            m[:_HDR.size] = _HDR.pack(_MAGIC, self._slot_size,
                                      self._nslots)
            self._file, self._mmap, self._path = f, m, path
            return True

    def _write_file(self, rec: Dict) -> None:
        if self._mmap is None:
            return
        try:
            data = json.dumps(rec, separators=(",", ":"),
                              default=str).encode()
        except (TypeError, ValueError):
            data = json.dumps({"seq": rec.get("seq"),
                               "kind": rec.get("kind")}).encode()
        limit = self._slot_size - _LEN.size
        if len(data) > limit:
            # keep the record parseable: fall back to the identity core
            data = json.dumps({"seq": rec.get("seq"), "ts": rec.get("ts"),
                               "kind": rec.get("kind"),
                               "truncated": True}).encode()[:limit]
        slot = next(self._slot) % self._nslots
        off = _HDR.size + slot * self._slot_size
        with self._lock:
            m = self._mmap
            if m is None:
                return
            m[off:off + _LEN.size] = _LEN.pack(len(data))
            m[off + _LEN.size:off + _LEN.size + len(data)] = data

    def close(self) -> None:
        with self._lock:
            m, f = self._mmap, self._file
            self._mmap = self._file = None
        if m is not None:
            m.flush()
            m.close()
        if f is not None:
            f.close()

    # ---- forensics --------------------------------------------------------

    @staticmethod
    def read_file(path: str) -> List[Dict]:
        """Recover the slot ring from a (possibly SIGKILL'd) process's
        flight file, oldest first. Torn or empty slots are skipped; a
        missing/undersized/foreign file yields []."""
        try:
            with open(path, "rb") as f:
                blob = f.read()
        except OSError:
            return []
        if len(blob) < _HDR.size:
            return []
        magic, slot_size, nslots = _HDR.unpack_from(blob, 0)
        if magic != _MAGIC or slot_size < _LEN.size or nslots == 0:
            return []
        out: List[Dict] = []
        for i in range(nslots):
            off = _HDR.size + i * slot_size
            if off + slot_size > len(blob):
                break
            (length,) = _LEN.unpack_from(blob, off)
            if length == 0 or length > slot_size - _LEN.size:
                continue
            raw = blob[off + _LEN.size:off + _LEN.size + length]
            try:
                rec = json.loads(raw.decode())
            except (ValueError, UnicodeDecodeError):
                continue                      # torn slot: that record only
            if isinstance(rec, dict):
                out.append(rec)
        out.sort(key=lambda r: r.get("seq", 0))
        return out
