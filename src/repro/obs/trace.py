"""Lightweight per-op tracing: spans, ambient context, propagation.

A *span* is one timed stage of one operation: `trace_id` names the
whole operation (stable across threads, processes, and transport
epochs), `span_id` names this stage, `parent_id` stitches it under the
stage that caused it. Trace context is ambient — a thread-local
`(trace_id, span_id)` pair — so instrumentation never threads explicit
arguments through call chains:

- same thread: a nested `span()` reads the ambient pair and parents
  itself automatically;
- executor hop (client daemon, GET I/O pool, writeback writer, leader
  pool): the submitter captures `current()` and the task re-installs it
  with `use()` (see `ObsPlane.bind_current`);
- process hop: the parent attaches the pair to the RPC payload
  (`host._ShardProxy._rpc`) and the worker adopts it around dispatch,
  so worker-side spans carry the parent's `trace_id` across both the
  pipe and the TCP transport — including across reconnect epochs (the
  pair is plain data; a retransmitted frame carries the same trace).

Span ids are `<pid-hex>.<counter>` so ids never collide across worker
processes; trace ids are 64-bit random hex. Finished spans land in a
bounded ring (newest win) — collection is `ObsPlane`'s job.
"""
from __future__ import annotations

import itertools
import os
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

# ambient trace context: (trace_id, span_id) of the innermost open span
_tls = threading.local()


def current() -> Optional[Tuple[str, str]]:
    """The ambient (trace_id, span_id) pair, or None outside any span."""
    return getattr(_tls, "ctx", None)


class use:
    """Install a (trace_id, span_id) pair as the ambient context for a
    region — the adoption half of every propagation hop."""
    __slots__ = ("_ctx", "_prev")

    def __init__(self, ctx: Optional[Tuple[str, str]]):
        self._ctx = tuple(ctx) if ctx is not None else None

    def __enter__(self):
        self._prev = getattr(_tls, "ctx", None)
        _tls.ctx = self._ctx
        return self._ctx

    def __exit__(self, *exc) -> bool:
        _tls.ctx = self._prev
        return False


class Span:
    """One finished (or in-flight) timed stage."""
    __slots__ = ("trace_id", "span_id", "parent_id", "site", "t0",
                 "dur_s", "tags", "pid", "epoch")

    def __init__(self, trace_id: str, span_id: str,
                 parent_id: Optional[str], site: str,
                 tags: Optional[Dict] = None,
                 epoch: Optional[int] = None):
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.site = site
        self.t0 = time.perf_counter()
        self.dur_s: Optional[float] = None
        self.tags = tags or {}
        self.pid = os.getpid()
        self.epoch = epoch

    def to_dict(self) -> Dict:
        return {"trace_id": self.trace_id, "span_id": self.span_id,
                "parent_id": self.parent_id, "site": self.site,
                "dur_us": None if self.dur_s is None
                else round(self.dur_s * 1e6, 1),
                "pid": self.pid, "epoch": self.epoch,
                "tags": dict(self.tags)}


class _SpanHandle:
    """Context manager for one span: opens it as a child of the ambient
    context, installs itself as the ambient context for the body, and
    reports the finished span back to the plane on exit."""
    __slots__ = ("_tracer", "_plane", "_site", "_tags", "_span", "_prev")

    def __init__(self, tracer: "Tracer", plane, site: str, tags: Dict):
        self._tracer = tracer
        self._plane = plane
        self._site = site
        self._tags = tags

    def __enter__(self) -> Span:
        parent = getattr(_tls, "ctx", None)
        if parent is None:
            trace_id = os.urandom(8).hex()
            parent_id = None
        else:
            trace_id, parent_id = parent
        span_id = f"{os.getpid():x}.{next(self._tracer._ids)}"
        self._span = Span(trace_id, span_id, parent_id, self._site,
                          self._tags, epoch=self._plane.epoch)
        self._prev = parent
        _tls.ctx = (trace_id, span_id)
        return self._span

    def __exit__(self, exc_type, exc, tb) -> bool:
        _tls.ctx = self._prev
        span = self._span
        span.dur_s = time.perf_counter() - span.t0
        if exc_type is not None:
            span.tags["error"] = exc_type.__name__
        self._plane._finish_span(span)
        return False


class _Noop:
    """Shared no-op context manager: what `span()` hands out when the
    plane is disabled, so disabled sites cost one branch and no
    allocation."""
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc) -> bool:
        return False


NOOP_CM = _Noop()


class Tracer:
    """Bounded ring of finished spans. Appends are a single GIL-atomic
    `deque.append` (maxlen evicts the oldest), so recording takes no
    lock — the same multi-writer discipline as `AtomicCounter`."""

    def __init__(self, capacity: int = 4096):
        self._ring: deque = deque(maxlen=capacity)
        self._ids = itertools.count(1)

    def start(self, plane, site: str, tags: Dict) -> _SpanHandle:
        return _SpanHandle(self, plane, site, tags)

    def add(self, span: Span) -> None:
        self._ring.append(span)

    def snapshot(self) -> List[Dict]:
        return [s.to_dict() for s in list(self._ring)]
