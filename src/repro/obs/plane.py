"""`ObsPlane` — the unified observability plane one store (or one
worker process) records into: tracing + latency histograms + flight
recorder behind a single handle.

Cost discipline (mirrors `repro.core.faults.FaultPlan`): the plane is
OFF by default — `StoreConfig.obs` is None and every instrumentation
site is guarded by one `obs is not None` check. An attached-but-
disabled plane (`enabled=False`) costs one early-returning method call
per site (`benchmarks/fault_soak.py` gates that at ≤2% of PUT-ack
latency). Only an enabled plane allocates spans and touches buckets.

Process model: the plane pickles into worker processes with the
`StoreConfig` that carries it (like `FaultPlan`, each process gets an
INDEPENDENT copy — fresh rings, fresh buckets, its own mmap flight file
bound under that worker's spill directory). The parent re-assembles the
global view by RPC-ing each worker's `snapshot()` and merging with
`merge_metric_snapshots` — histograms sum bucket-wise, spans stitch by
`trace_id`, flight events concatenate.

`ISTORE_METRICS_DUMP=<path>` registers an atexit hook that dumps the
merged Prometheus text of every live plane in the process.
"""
from __future__ import annotations

import atexit
import os
import weakref
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from repro.obs import trace as _trace
from repro.obs.metrics import (LatencyHistogram, merge_counts, summarize,
                               to_prometheus)
from repro.obs.recorder import FlightRecorder
from repro.obs.sites import HISTOGRAM_SITES, METRIC_SITES
from repro.obs.trace import NOOP_CM, Tracer

_PLANES: "weakref.WeakSet[ObsPlane]" = weakref.WeakSet()
_ATEXIT_INSTALLED = [False]


def _atexit_dump(path: str) -> None:
    planes = [p for p in list(_PLANES) if p.enabled]
    if not planes:
        return
    merged = merge_metric_snapshots([p.snapshot() for p in planes])
    try:
        with open(path, "w") as f:
            f.write(to_prometheus(merged))
    except OSError:
        pass                                  # best-effort exit hook


def _register_plane(plane: "ObsPlane") -> None:
    _PLANES.add(plane)
    path = os.environ.get("ISTORE_METRICS_DUMP")
    if path and not _ATEXIT_INSTALLED[0]:
        _ATEXIT_INSTALLED[0] = True
        atexit.register(_atexit_dump, path)


class ObsPlane:
    """One process's observability plane; see the module docstring."""

    def __init__(self, *, enabled: bool = True, name: str = "",
                 span_capacity: int = 4096,
                 event_capacity: int = 256):
        self.enabled = enabled
        self.name = name
        self.epoch: Optional[int] = None
        self._span_capacity = span_capacity
        self._event_capacity = event_capacity
        self._tracer = Tracer(span_capacity)
        self._hists: Dict[str, LatencyHistogram] = {
            site: LatencyHistogram() for site in sorted(HISTOGRAM_SITES)}
        self._recorder = FlightRecorder(event_capacity)
        # forensics loaded from dead workers' flight files; leaf lock.
        # (Constructor-time import: repro.core layers import repro.obs,
        # so a module-level core import here would be circular.)
        from repro.core.locks import make_lock
        self._flock = make_lock("plane.ObsPlane._flock")
        self._forensics: List[Dict] = []
        _register_plane(self)

    # ---- site API (every call below takes a literal registered in
    # ---- obs.METRIC_SITES; the metric_site lint rule enforces it) ----

    def span(self, site: str, **tags):
        """Context manager opening one span as a child of the ambient
        context. Disabled plane: a shared no-op CM, no allocation."""
        if not self.enabled:
            return NOOP_CM
        return self._tracer.start(self, site, tags)

    def record(self, site: str, value_us: float) -> None:
        """One lock-free histogram sample (microseconds)."""
        if self.enabled:
            self._hists[site].record(value_us)

    def event(self, site: str, **fields) -> None:
        """One flight-recorder event (ring + mmap mirror)."""
        if self.enabled:
            if self.epoch is not None:
                fields.setdefault("epoch", self.epoch)
            self._recorder.event(site, **fields)

    # ---- context propagation ---------------------------------------------

    def ctx(self) -> Optional[Tuple[str, str]]:
        """The ambient (trace_id, span_id) pair to attach to an RPC or
        executor hop; None when disabled or outside any span."""
        if not self.enabled:
            return None
        return _trace.current()

    def adopt(self, ctx: Optional[Tuple[str, str]]):
        """Install a propagated context pair for a region (worker-side
        dispatch, executor task bodies)."""
        return _trace.use(ctx)

    def bind_current(self, fn: Callable) -> Callable:
        """Close `fn` over the ambient context so an executor hop keeps
        the trace: the returned callable re-installs the submitter's
        context. Returns `fn` unchanged when there is nothing to carry."""
        ctx = self.ctx()
        if ctx is None:
            return fn

        def _traced(*a, **kw):
            with _trace.use(ctx):
                return fn(*a, **kw)

        return _traced

    # ---- lifecycle --------------------------------------------------------

    def bind_flight(self, path: str) -> bool:
        """Attach the mmap flight mirror (first bind wins — one file
        per crash domain/process)."""
        if not self.enabled:
            return False
        return self._recorder.bind(path)

    @property
    def flight_path(self) -> Optional[str]:
        return self._recorder.path

    def set_epoch(self, epoch: int) -> None:
        """Adopt a (new) connection epoch: subsequent spans and events
        are tagged with it, so post-reconnect activity is attributable
        to its epoch."""
        self.epoch = epoch

    def close(self) -> None:
        self._recorder.close()

    def _finish_span(self, span) -> None:
        self._tracer.add(span)
        # mirror to the flight file so a SIGKILL'd worker's spans are
        # recoverable (tagged with their epoch) instead of lost
        if self._recorder.path is not None:
            d = span.to_dict()
            d["kind"] = "span"
            self._recorder.mirror(d)

    # ---- forensics --------------------------------------------------------

    def add_forensics(self, source: str, records: List[Dict],
                      **tags) -> None:
        """Attach records recovered from a dead process's flight file;
        they surface under `snapshot()["forensics"]`, tagged dead=True
        plus whatever the caller knows (shard id, last epoch)."""
        with self._flock:
            self._forensics.append(
                {"source": source, "dead": True, **tags,
                 "records": list(records)})

    # ---- export -----------------------------------------------------------

    def snapshot(self) -> Dict:
        hists = {site: {"buckets": h.snapshot()}
                 for site, h in self._hists.items()}
        for site, d in hists.items():
            d.update(summarize(d["buckets"]))
        with self._flock:
            forensics = [dict(f) for f in self._forensics]
        return {"enabled": self.enabled, "name": self.name,
                "pid": os.getpid(), "epoch": self.epoch,
                "sites": sorted(METRIC_SITES),
                "histograms": hists,
                "spans": self._tracer.snapshot(),
                "events": self._recorder.snapshot(),
                "forensics": forensics,
                "flight_path": self._recorder.path}

    # ---- pickling (into worker processes) ---------------------------------

    def __getstate__(self) -> Dict:
        return {"enabled": self.enabled, "name": self.name,
                "span_capacity": self._span_capacity,
                "event_capacity": self._event_capacity}

    def __setstate__(self, state: Dict) -> None:
        self.__init__(enabled=state["enabled"], name=state["name"],
                      span_capacity=state["span_capacity"],
                      event_capacity=state["event_capacity"])


def merge_metric_snapshots(snaps: Iterable[Dict]) -> Dict:
    """Merge plane snapshots (parent + per-worker) into one store-wide
    view: histograms sum bucket-wise (then re-summarized), spans /
    events / forensics concatenate. Input dicts are not mutated."""
    snaps = [s for s in snaps if s]
    out: Dict = {"enabled": any(s.get("enabled") for s in snaps),
                 "pid": os.getpid(),
                 "sites": sorted(METRIC_SITES),
                 "histograms": {}, "spans": [], "events": [],
                 "forensics": []}
    all_sites: set = set()
    for s in snaps:
        all_sites.update(s.get("histograms", {}))
    for site in sorted(all_sites):
        counts = merge_counts(
            [s["histograms"][site]["buckets"] for s in snaps
             if site in s.get("histograms", {})])
        out["histograms"][site] = {"buckets": counts, **summarize(counts)}
    for s in snaps:
        out["spans"].extend(s.get("spans", ()))
        for ev in s.get("events", ()):
            ev = dict(ev)
            if s.get("name"):
                ev.setdefault("source", s["name"])
            out["events"].append(ev)
        out["forensics"].extend(s.get("forensics", ()))
    counters: Dict[str, float] = {}
    for s in snaps:
        for k, v in (s.get("counters") or {}).items():
            counters[k] = counters.get(k, 0) + v
    if counters:
        out["counters"] = counters
    return out
